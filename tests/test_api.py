"""LDAEngine front-door tests (repro/lda/api.py).

The load-bearing properties:
  1. Validation is centralized: every bad knob fails at LDAConfig
     construction (one place), and the engine rejects bad backends.
  2. The old trainer constructors are deprecation shims: direct use warns,
     the engine path does not.
  3. ONE checkpoint format: payloads written under any (backend, format)
     pair restore into any other with topics bit-equal — dense <-> hybrid
     in-process, single <-> distributed in a forged-device subprocess.
  4. Legacy padded-"topics" payloads still restore.
  5. The scikit-style lifecycle (fit / resume / score) behaves: LLPT
     rises, resume picks up the newest checkpoint, fit continues from it.
"""

import subprocess
import sys
import textwrap
import warnings

import numpy as np
import pytest

import jax

from repro.checkpoint import CheckpointManager
from repro.lda.api import LDAEngine
from repro.lda.corpus import synthetic_lda_corpus
from repro.lda.model import LDAConfig

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def corpus():
    # raw (UNrelabeled) on purpose: the engine owns corpus prep
    return synthetic_lda_corpus(0, n_docs=60, n_words=80, n_topics=8,
                                mean_doc_len=40)


def _cfg(**kw):
    base = dict(n_topics=16, tile_size=512, eval_every=5, fused=True)
    base.update(kw)
    return LDAConfig(**base)


# ---------------------------------------------------------------------------
# 1. centralized validation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bad", [
    dict(n_topics=0),
    dict(sampler="four_branch"),
    dict(impl="cuda"),
    dict(format="csr"),
    dict(tail_sampler="magic"),
    dict(g=0),
    dict(eval_every=0),
    dict(alpha=-1.0),
    dict(beta=0.0),
    dict(d_capacity=0),
    dict(survivor_capacity=-3),
])
def test_config_validation_centralized(bad):
    """Every knob fails at CONFIG construction, not inside a backend."""
    kw = dict(n_topics=8)
    kw.update(bad)
    with pytest.raises(ValueError):
        LDAConfig(**kw)


def test_engine_rejects_unknown_backend(corpus):
    with pytest.raises(ValueError, match="backend"):
        LDAEngine(corpus, _cfg(), backend="tpu_pod")


def test_engine_single_rejects_mesh(corpus):
    from repro.runtime.compat import make_mesh
    with pytest.raises(ValueError, match="mesh"):
        LDAEngine(corpus, _cfg(), backend="single",
                  mesh=make_mesh((1, 1), ("data", "model")))


# ---------------------------------------------------------------------------
# 2. deprecation shims
# ---------------------------------------------------------------------------

def test_direct_trainer_construction_raises(corpus):
    from repro.lda.trainer import LDATrainer
    with pytest.raises(TypeError, match="LDAEngine"):
        LDATrainer(corpus, _cfg())


def test_direct_dist_trainer_construction_raises(corpus):
    from repro.lda.distributed import DistLDATrainer, PSDistTrainer
    from repro.runtime.compat import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    with pytest.raises(TypeError, match="LDAEngine"):
        DistLDATrainer(corpus, _cfg(), mesh)
    with pytest.raises(TypeError, match="LDAEngine"):
        PSDistTrainer(corpus, _cfg(), mesh)


def test_engine_path_does_not_warn(corpus):
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        LDAEngine(corpus, _cfg(), backend="single")


def test_auto_backend_single_device(corpus):
    # the test suite runs on one real CPU device
    eng = LDAEngine(corpus, _cfg())
    assert eng.backend_name == "single"


# ---------------------------------------------------------------------------
# 3./5. lifecycle + one checkpoint format (in-process: dense <-> hybrid)
# ---------------------------------------------------------------------------

def test_fit_score_lifecycle(corpus):
    eng = LDAEngine(corpus, _cfg(), backend="single")
    with pytest.raises(RuntimeError, match="fit"):
        eng.state  # no state before fit/resume
    hist = eng.fit(20)
    assert hist["llpt"][-1] > hist["llpt"][0], "LLPT must rise"
    assert eng.iteration == 20
    # 20 is an eval boundary: score() at the final state == last history eval
    assert eng.score() == pytest.approx(hist["llpt"][-1])
    # engine-owned prep: the raw corpus was frequency-relabeled
    assert eng.word_map is not None
    assert np.all(np.diff(eng.corpus.word_token_counts) <= 0)
    # history accumulates across fit calls
    eng.fit(5)
    assert eng.history["iteration"][-1] == 25


def test_checkpoint_roundtrip_dense_hybrid(corpus, tmp_path):
    """Canonical payloads cross live-state formats with topics bit-equal."""
    mgr = CheckpointManager(str(tmp_path))
    eng = LDAEngine(corpus, _cfg(format="dense"), backend="single",
                    checkpoint_manager=mgr)
    eng.fit(10)
    eng.save()
    p0 = eng.host_payload()

    eng_h = LDAEngine(corpus, _cfg(format="hybrid"), backend="single",
                      checkpoint_manager=mgr).resume()
    assert eng_h.iteration == 10
    p1 = eng_h.host_payload()
    assert np.array_equal(p0["topics_global"], p1["topics_global"])
    assert np.array_equal(p0["key"], p1["key"])

    # reverse: train hybrid, restore into dense
    eng_h.fit(5)
    eng_h.save()
    eng_d = LDAEngine(corpus, _cfg(format="dense"), backend="single",
                      checkpoint_manager=mgr).resume()
    assert eng_d.iteration == 15
    assert np.array_equal(eng_h.host_payload()["topics_global"],
                          eng_d.host_payload()["topics_global"])
    # counts are derived state: the restored dense W equals the hybrid's
    W_h = eng_h._backend.dense_W(eng_h.state)
    W_d = eng_d._backend.dense_W(eng_d.state)
    assert np.array_equal(W_h, W_d)


def test_resume_continues_training(corpus, tmp_path):
    eng = LDAEngine(corpus, _cfg(), backend="single",
                    checkpoint_dir=str(tmp_path))
    eng.fit(10, checkpoint_every=5)
    eng2 = LDAEngine(corpus, _cfg(), backend="single",
                     checkpoint_dir=str(tmp_path)).resume()
    assert eng2.iteration == 10
    hist = eng2.fit(5)
    assert eng2.iteration == 15
    assert hist["iteration"][0] > 10


def test_resume_without_manager_raises(corpus):
    with pytest.raises(ValueError, match="checkpoint"):
        LDAEngine(corpus, _cfg(), backend="single").resume()


# ---------------------------------------------------------------------------
# 4. legacy + malformed payloads
# ---------------------------------------------------------------------------

def test_legacy_padded_topics_payload_restores(corpus):
    eng = LDAEngine(corpus, _cfg(), backend="single")
    eng.fit(5)
    # what an old single-trainer checkpoint looked like: PADDED topics
    legacy = eng.trainer.host_payload(eng.state)
    assert "topics" in legacy and "topics_global" not in legacy
    eng2 = LDAEngine(corpus, _cfg(), backend="single").restore(legacy)
    assert eng2.iteration == 5
    assert np.array_equal(eng.host_payload()["topics_global"],
                          eng2.host_payload()["topics_global"])


def test_malformed_payload_actionable_errors(corpus):
    eng = LDAEngine(corpus, _cfg(), backend="single")
    key = np.asarray(jax.random.key_data(jax.random.PRNGKey(0)))
    with pytest.raises(ValueError, match="different corpus"):
        eng.restore({"topics_global": np.zeros(3, np.int32),
                     "key": key, "iteration": 1})
    with pytest.raises(ValueError, match="topics"):
        eng.restore({"key": key, "iteration": 1})


def test_trainer_payload_shape_error_is_valueerror(corpus):
    """The finished bare-assert sweep: a wrong-shape checkpoint raises an
    actionable ValueError, not AssertionError."""
    from repro.lda.trainer import LDATrainer
    tr = LDATrainer(corpus, _cfg(), _from_engine=True)
    key = np.asarray(jax.random.key_data(jax.random.PRNGKey(0)))
    with pytest.raises(ValueError, match="padded corpus"):
        tr.state_from_payload({"topics": np.zeros(7, np.int32),
                               "key": key, "iteration": 0})


# ---------------------------------------------------------------------------
# 3b. cross-BACKEND round trip (single <-> distributed, forged devices)
# ---------------------------------------------------------------------------

_PRELUDE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import tempfile
import numpy as np, jax
from repro.checkpoint import CheckpointManager
from repro.lda.api import LDAEngine
from repro.lda.corpus import synthetic_lda_corpus
from repro.lda.model import LDAConfig

corpus = synthetic_lda_corpus(0, n_docs=60, n_words=80, n_topics=8,
                              mean_doc_len=40)
cfg = LDAConfig(n_topics=16, tile_size=512, eval_every=4, fused=True)
mgr = CheckpointManager(tempfile.mkdtemp())
"""


@pytest.mark.slow
def test_checkpoint_roundtrip_single_to_distributed():
    """backend='single' format='dense' -> backend='distributed'
    format='hybrid' and back: topics bit-equal, counts conserved, and the
    restored engines keep training."""
    body = _PRELUDE + textwrap.dedent("""
    import dataclasses
    eng = LDAEngine(corpus, cfg, backend="single", checkpoint_manager=mgr)
    eng.fit(8)
    eng.save()
    p0 = eng.host_payload()

    cfg_h = dataclasses.replace(cfg, format="hybrid")
    eng_d = LDAEngine(corpus, cfg_h, backend="distributed",
                      checkpoint_manager=mgr, pad_multiple=256).resume()
    assert eng_d.backend_name == "distributed"
    assert eng_d.iteration == 8
    p1 = eng_d.host_payload()
    assert np.array_equal(p0["topics_global"], p1["topics_global"])
    D, W = eng_d.trainer.gather_global(eng_d.state)
    assert D.sum() == corpus.n_tokens == W.sum()

    # reverse: distributed hybrid -> single dense, bit-equal again
    eng_d.fit(4)
    eng_d.save()
    eng_s = LDAEngine(corpus, cfg, backend="single",
                      checkpoint_manager=mgr).resume()
    assert eng_s.iteration == 12
    assert np.array_equal(eng_d.host_payload()["topics_global"],
                          eng_s.host_payload()["topics_global"])
    hist = eng_s.fit(4)
    assert eng_s.iteration == 16 and len(hist["llpt"]) >= 1
    print("OK")
    """)
    proc = subprocess.run([sys.executable, "-c", body],
                          capture_output=True, text=True, timeout=900,
                          cwd=".")
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "OK" in proc.stdout


@pytest.mark.slow
def test_auto_backend_picks_distributed_on_multi_device():
    body = _PRELUDE + textwrap.dedent("""
    eng = LDAEngine(corpus, cfg)
    assert eng.backend_name == "distributed", eng.backend_name
    hist = eng.fit(4)
    assert hist["llpt"][-1] >= hist["llpt"][0] - 0.2
    print("OK")
    """)
    proc = subprocess.run([sys.executable, "-c", body],
                          capture_output=True, text=True, timeout=900,
                          cwd=".")
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "OK" in proc.stdout
