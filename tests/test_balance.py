"""Hierarchical workload balancing tests (paper §V-A, DESIGN.md SS9).

The load-bearing properties:
  1. TilePlan invariants (hypothesis-driven): tiles exactly cover all
     tokens with no overlap or gaps; per-tile (first, last) word-run
     metadata matches the actual tile contents; ``max_tiles_per_word`` is
     the EXACT dissection depth (the old ``ceil(count/tile)+1`` bound
     over-counted) and bounds the brute-force depth of every word.
  2. The tile-scheduled kernels (``sample_fused_tiled``,
     ``sample_sparse_tiled``) are bit-equal to their per-token-gather
     counterparts — same row values in, same bits out.
  3. ``balance="tiles"`` is a pure performance knob end to end: the fused
     pipelines (dense xla/pallas, hybrid exact/sparse tail) produce
     bit-identical topics AND counts with tiling on or off, window
     engaged or cond-fallback.
  4. ``assign_token_shards``: every token assigned exactly once, loads
     balanced within the LPT bound, >threshold words dissected.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from _hyp import given, settings, st
from repro.core import balance
from repro.lda.corpus import from_documents, relabel_by_frequency, zipf_corpus
from repro.lda.model import LDAConfig
from repro.lda.trainer import LDATrainer
from repro.train.lda_step import plan_tile_capacity, plan_window

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# 1. TilePlan invariants
# ---------------------------------------------------------------------------

def _brute_force_depth(word_ids: np.ndarray, tile_size: int) -> int:
    """True dissection depth: tiles touched by any single word's run."""
    if len(word_ids) == 0:
        return 1
    tile_of = np.arange(len(word_ids)) // tile_size
    return max(len(np.unique(tile_of[word_ids == v]))
               for v in np.unique(word_ids))


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000), tile_size=st.integers(1, 300))
def test_tile_plan_invariants(seed, tile_size):
    rng = np.random.default_rng(seed)
    n_words = int(rng.integers(1, 60))
    n = int(rng.integers(1, 2000))
    word_ids = np.sort(rng.integers(0, n_words, n)).astype(np.int32)
    plan = balance.build_tiles_from_word_ids(word_ids, tile_size)
    # exact cover: contiguous [t·ts, min((t+1)·ts, n)) ranges partition T
    assert plan.n_tiles == -(-n // tile_size)
    sizes = [min(tile_size, n - t * tile_size) for t in range(plan.n_tiles)]
    assert sum(sizes) == n and min(sizes) > 0          # no overlap, no gap
    for t in range(plan.n_tiles):
        lo, hi = t * tile_size, t * tile_size + sizes[t]
        seg = word_ids[lo:hi]
        assert plan.tile_first_word[t] == seg[0]       # sorted ⇒ min
        assert plan.tile_last_word[t] == seg[-1]
        assert len(np.unique(seg)) <= plan.max_words_per_tile
    # the dissection depth is EXACT, not just an upper bound
    assert plan.max_tiles_per_word == _brute_force_depth(word_ids, tile_size)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_build_tiles_corpus_matches_generic(seed):
    """Corpus CSR route == generic word-id route, field by field."""
    rng = np.random.default_rng(seed)
    n_words = int(rng.integers(2, 40))
    docs = [rng.integers(0, n_words, rng.integers(1, 50)).tolist()
            for _ in range(int(rng.integers(1, 20)))]
    c = from_documents(docs, n_words)
    ts = int(rng.integers(1, 200))
    a = balance.build_tiles(c, ts)
    b = balance.build_tiles_from_word_ids(c.word_ids, ts)
    assert np.array_equal(a.tile_first_word, b.tile_first_word)
    assert np.array_equal(a.tile_last_word, b.tile_last_word)
    assert (a.n_tiles, a.max_words_per_tile, a.max_tiles_per_word) \
        == (b.n_tiles, b.max_words_per_tile, b.max_tiles_per_word)


def test_tiles_spanned_exact_small_words():
    """The fixed bound: words smaller than one tile span 1-2 tiles by
    alignment, never the old ceil+1 over-count."""
    # word of 3 tokens entirely inside tile 0 → exactly 1
    assert balance.tiles_spanned(np.array([2]), np.array([3]), 8)[0] == 1
    # word of 3 tokens straddling the boundary at 8 → exactly 2
    assert balance.tiles_spanned(np.array([6]), np.array([3]), 8)[0] == 2
    # absent word → 0 tiles
    assert balance.tiles_spanned(np.array([5]), np.array([0]), 8)[0] == 0
    # 16-token word aligned at 0 with tile 8 → exactly 2 (old bound: 3)
    assert balance.tiles_spanned(np.array([0]), np.array([16]), 8)[0] == 2


def test_build_tiles_rejects_unsorted():
    with pytest.raises(ValueError, match="sorted"):
        balance.build_tiles_from_word_ids(np.array([3, 1, 2]), 2)


def test_empty_corpus_tile_plan():
    plan = balance.build_tiles_from_word_ids(np.zeros(0, np.int32), 16)
    assert plan.n_tiles == 0 and plan.max_tiles_per_word == 1


# ---------------------------------------------------------------------------
# 2. tiled kernels == per-token-gather kernels, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,K,win", [(100, 64, 16), (300, 130, 64),
                                     (257, 512, 128)])
def test_sample_fused_tiled_bit_equal(n, K, win):
    from repro.kernels.sample_fused import sample_fused, sample_fused_tiled
    rng = np.random.default_rng(n + K)
    V = 200
    w_hat = (rng.random((V, K)) * 0.01).astype(np.float32)
    lo = int(rng.integers(0, V - win))
    word_ids = np.sort(rng.integers(lo, lo + win, n)).astype(np.int32)
    d = (rng.integers(0, 50, (n, K))
         * (rng.random((n, K)) < 0.15)).astype(np.int32)
    u = rng.random(n).astype(np.float32)
    ref = sample_fused(jnp.asarray(u), jnp.asarray(d),
                       jnp.asarray(w_hat[word_ids]), alpha=0.1,
                       interpret=True)
    got = sample_fused_tiled(jnp.asarray(u), jnp.asarray(d),
                             jnp.asarray(w_hat), jnp.asarray(word_ids),
                             jnp.int32(word_ids.min()), alpha=0.1,
                             win_words=win, interpret=True)
    for a, b in zip(ref, got):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_sample_sparse_tiled_bit_equal():
    from repro.core.sparse import pack_pairs
    from repro.kernels.sample_sparse import sample_sparse, sample_sparse_tiled
    rng = np.random.default_rng(7)
    n, L, K, V, win = 300, 8, 64, 150, 32
    word_ids = np.sort(rng.integers(40, 40 + win, n)).astype(np.int32)
    idx = np.zeros((n, L), np.int32)
    val = np.zeros((n, L), np.int32)
    for i in range(n):
        nnz = rng.integers(0, L + 1)
        idx[i] = rng.choice(K, L, replace=False)
        val[i, :nnz] = rng.integers(1, 30, nnz)
    packed = pack_pairs(jnp.asarray(idx), jnp.asarray(val))
    k1_w = rng.integers(0, K, V).astype(np.int32)
    a1_w = (rng.random(V) * 0.02).astype(np.float32)
    qp_w = (rng.random(V) * 0.05).astype(np.float32)
    w_at = jnp.asarray((rng.random((n, L)) * 0.01).astype(np.float32))
    b1 = jnp.asarray(rng.integers(0, 20, n).astype(np.float32))
    u = jnp.asarray(rng.random(n).astype(np.float32))
    ref = sample_sparse(u, packed, w_at, jnp.asarray(k1_w[word_ids]),
                        jnp.asarray(a1_w[word_ids]), b1,
                        jnp.asarray(qp_w[word_ids]), alpha=0.2,
                        interpret=True)
    got = sample_sparse_tiled(u, packed, w_at, jnp.asarray(word_ids),
                              jnp.int32(word_ids.min()), jnp.asarray(k1_w),
                              jnp.asarray(a1_w), jnp.asarray(qp_w), b1,
                              alpha=0.2, win_words=win, interpret=True)
    for a, b in zip(ref, got):
        assert np.array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# 3. balance="tiles" is a pure perf knob in the fused pipelines
# ---------------------------------------------------------------------------

def _pipeline_trajectory(corpus, cfg, n_iters=6, force_window=None):
    tr = LDATrainer(corpus, cfg, _from_engine=True)
    pipe = tr.fused_pipeline()
    if force_window is not None:
        # engage the word-window path even on a tiny test vocabulary
        pipe.WINDOW_VOCAB_FRACTION = 1
        pipe.win_words = force_window
    fs = pipe.from_lda_state(tr.init_state())
    for _ in range(n_iters // 2):
        fs, _, _ = pipe.run_fused(fs, 2)       # replans between scans
    st = pipe.to_lda_state(fs)
    return np.asarray(st.topics), np.asarray(st.D), np.asarray(st.W)


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_tiled_pipeline_bit_equal_dense(small_corpus, impl):
    base = LDAConfig(n_topics=16, tile_size=512, impl=impl)
    ref = _pipeline_trajectory(small_corpus, base)
    for force in (None, 24):                   # tile-capacity only / +window
        cfg = LDAConfig(n_topics=16, tile_size=512, impl=impl,
                        balance="tiles")
        got = _pipeline_trajectory(small_corpus, cfg, force_window=force)
        for a, b in zip(ref, got):
            assert np.array_equal(a, b), (impl, force)


@pytest.mark.parametrize("tail_sampler", ["exact", "sparse"])
def test_tiled_pipeline_bit_equal_hybrid(small_corpus, tail_sampler):
    base = LDAConfig(n_topics=16, tile_size=512, format="hybrid",
                     tail_sampler=tail_sampler)
    ref = _pipeline_trajectory(small_corpus, base)
    cfg = LDAConfig(n_topics=16, tile_size=512, format="hybrid",
                    tail_sampler=tail_sampler, balance="tiles")
    got = _pipeline_trajectory(small_corpus, cfg, force_window=24)
    for a, b in zip(ref, got):
        assert np.array_equal(a, b), tail_sampler


def test_tiny_window_forces_fallback_still_bit_equal(small_corpus):
    """A window far below every chunk span exercises the cond fallback on
    every chunk — correctness must never depend on the plan."""
    base = LDAConfig(n_topics=16, tile_size=512)
    ref = _pipeline_trajectory(small_corpus, base)
    cfg = LDAConfig(n_topics=16, tile_size=512, balance="tiles")
    got = _pipeline_trajectory(small_corpus, cfg, force_window=2)
    for a, b in zip(ref, got):
        assert np.array_equal(a, b)


def test_balance_knob_validation():
    with pytest.raises(ValueError, match="balance"):
        LDAConfig(n_topics=8, balance="magic")
    assert LDAConfig(n_topics=8, balance="tiles").balance == "tiles"


def test_plan_helpers():
    # window: pow2 bucketing, floored, vocab-clamped
    assert plan_window(100, 10_000) == 128
    assert plan_window(1, 10_000) == 64          # floor
    assert plan_window(9_000, 3_000) == 3_000    # vocab clamp
    # tile capacity: working-set cap at 256 KB / (4·K)
    assert plan_tile_capacity(10 ** 9, 10 ** 9, 64) == 1024
    assert plan_tile_capacity(10 ** 9, 10 ** 9, 256) == 256
    # survivor EMA can shrink tiles below the budget
    assert plan_tile_capacity(2_000, 10 ** 9, 64) <= 1024


# ---------------------------------------------------------------------------
# 4. device-level token-balanced shard assignment
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), n_shards=st.integers(1, 12))
def test_assign_token_shards_properties(seed, n_shards):
    rng = np.random.default_rng(seed)
    n_words = int(rng.integers(2, 50))
    docs = [rng.integers(0, n_words, rng.integers(1, 60)).tolist()
            for _ in range(int(rng.integers(1, 30)))]
    c = from_documents(docs, n_words)
    token_shard, loads = balance.assign_token_shards(c, n_shards)
    # every token assigned exactly once; loads consistent
    assert token_shard.shape == (c.n_tokens,)
    assert np.all((token_shard >= 0) & (token_shard < n_shards))
    assert np.array_equal(np.bincount(token_shard, minlength=n_shards),
                          loads)
    # LPT with units ≤ ceil(N/4S): max load ≤ mean + unit ⇒ max/mean small
    if c.n_tokens >= 4 * n_shards:
        unit = -(-c.n_tokens // (4 * n_shards))
        assert loads.max() <= c.n_tokens / n_shards + unit


def test_assign_token_shards_dissects_head_word():
    """A power-law head word larger than any shard's fair share MUST be
    dissected across shards — the case document chunking cannot fix."""
    c = zipf_corpus(3, n_docs=150, n_words=400, exponent=1.7,
                    mean_doc_len=80)
    c, _ = relabel_by_frequency(c)
    head_count = int(c.word_token_counts[0])
    n_shards = 8
    assert head_count > c.n_tokens / n_shards    # head dwarfs a fair share
    token_shard, loads = balance.assign_token_shards(c, n_shards)
    head_shards = np.unique(token_shard[c.word_ids == 0])
    assert len(head_shards) >= 2                 # dissected
    assert loads.max() / loads.mean() <= 1.25    # and balanced


def test_shard_corpus_tiles_metadata(skewed_corpus):
    """shard_corpus(balance="tiles"): shared-doc metadata is consistent."""
    from repro.lda.distributed import shard_corpus
    sc = shard_corpus(skewed_corpus, 4, pad_multiple=64, balance="tiles")
    assert sc.owns is not None
    # every real doc has exactly ONE owner row across shards
    owners = []
    for s in range(4):
        nd = int(sc.docs_per_shard[s])
        owners.extend(sc.doc_map[s][:nd][sc.owns[s][:nd] > 0].tolist())
    assert sorted(owners) == list(range(skewed_corpus.n_docs))
    # token loads balanced (the point of the assignment)
    tps = sc.tokens_per_shard
    assert tps.max() / tps.mean() <= 1.25
    # shared_rows point at rows whose doc_map entry is the shared doc
    n_shared = sc.shared_rows.shape[1]
    for s in range(4):
        for j in range(n_shared):
            row = sc.shared_rows[s, j]
            if row < sc.m_local:
                g = sc.doc_map[s][row]
                # that doc's token slots carry slot j
                tok = (sc.doc_ids[s] == row) & (sc.mask[s] > 0)
                if tok.any():
                    assert np.all(sc.shared_slot[s][tok] == j), (s, j, g)
