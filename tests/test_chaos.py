"""Chaos/recovery regression suite (DESIGN.md §11).

Every test injects a deterministic fault through ``repro.runtime.chaos``
and pins the recovery contract: a supervised ``fit`` must converge to a
state BITWISE equal to the uninterrupted run (restore + deterministic
replay — counts are derived from topics, so a checkpoint fully determines
the future), transient faults must be absorbed in place (no restart),
and detection tripwires (crc32 shard checks, count invariants) must fire
as restartable errors rather than poisoning the model.

All tests run on CPU; the forged multi-device case is ``slow``.
"""

import subprocess
import sys
import textwrap
import time
import warnings

import numpy as np
import pytest

import repro.train.lda_step as lda_step
from repro.lda import invariants
from repro.lda.api import LDAEngine, SupervisePolicy
from repro.lda.corpus import relabel_by_frequency, synthetic_lda_corpus
from repro.lda.model import LDAConfig
from repro.runtime import chaos
from repro.runtime.fault import backoff_delay, is_oom_error
from repro.train.lda_step import PrefetchTimeout, _Prefetcher

pytestmark = pytest.mark.chaos

KEYS = ("topics_global", "key", "iteration")


@pytest.fixture(scope="module")
def corpus():
    c = synthetic_lda_corpus(7, n_docs=50, n_words=60, n_topics=6,
                             mean_doc_len=25)
    c, _ = relabel_by_frequency(c)
    return c


def _cfg(**kw):
    kw.setdefault("n_topics", 8)
    kw.setdefault("tile_size", 256)
    kw.setdefault("eval_every", 4)
    kw.setdefault("seed", 3)
    return LDAConfig(**kw)


def _ref(corpus, cfg, n_iters):
    e = LDAEngine(corpus, cfg, backend="single")
    e.fit(n_iters)
    return e.host_payload()


def _same(a, b):
    return all(np.array_equal(np.asarray(a[k]), np.asarray(b[k]))
               for k in KEYS)


def _policy(**kw):
    kw.setdefault("checkpoint_every", 3)
    kw.setdefault("backoff_base", 0.0)
    return SupervisePolicy(**kw)


# -- supervised restart → bitwise-identical state ---------------------------

@pytest.mark.parametrize("format", ["dense", "hybrid"])
def test_killed_at_step_resident_bitwise(corpus, tmp_path, format):
    cfg = _cfg(format=format)
    ref = _ref(corpus, cfg, 10)
    e = LDAEngine(corpus, cfg, backend="single", checkpoint_dir=str(tmp_path))
    with chaos.active(chaos.FaultPlan(raise_at_steps=(7,))):
        hist = e.fit(10, supervise=_policy())
    rep = hist["restart_report"]
    assert rep.restarts == 1 and rep.completed_steps == 10
    assert rep.resumed_from == [6]
    assert "InjectedFault" in rep.faults[0]
    assert len(rep.recovery_seconds) == 1
    assert _same(ref, e.host_payload())


@pytest.mark.parametrize("format", ["dense", "hybrid"])
def test_mid_epoch_kill_streamed_bitwise(corpus, tmp_path, format):
    """Killed with an epoch OPEN: the newest checkpoint is a mid-epoch
    stream payload; resume re-derives counts + deltas and continues
    bit-identically (the PR5 stream-payload contract, now exercised
    through the supervisor)."""
    cfg = _cfg(format=format, corpus_residency="streamed", stream_shards=4)
    ref = _ref(corpus, cfg, 8)
    e = LDAEngine(corpus, cfg, backend="single", checkpoint_dir=str(tmp_path))
    pol = _policy(checkpoint_shards=1)
    with chaos.active(chaos.FaultPlan(raise_at_shards=((5, 2),))):
        hist = e.fit(8, supervise=pol)
    rep = hist["restart_report"]
    assert rep.restarts == 1
    assert rep.resumed_from == [5]      # restored INTO the open epoch 5
    assert _same(ref, e.host_payload())


def test_checkpoint_shards_needs_streamed_single(corpus, tmp_path):
    e = LDAEngine(corpus, _cfg(), backend="single",
                  checkpoint_dir=str(tmp_path))
    with pytest.raises(ValueError, match="streamed"):
        e.fit(4, supervise=_policy(checkpoint_shards=1))


def test_supervise_needs_manager(corpus):
    e = LDAEngine(corpus, _cfg(), backend="single")
    with pytest.raises(ValueError, match="checkpoint"):
        e.fit(4, supervise=True)


def test_max_restarts_exhausted_propagates(corpus, tmp_path):
    e = LDAEngine(corpus, _cfg(), backend="single",
                  checkpoint_dir=str(tmp_path))
    plan = chaos.FaultPlan(raise_at_steps=(2,), repeat=True)
    with chaos.active(plan), pytest.raises(chaos.InjectedFault):
        e.fit(6, supervise=_policy(max_restarts=2))


def test_nonrestartable_fault_propagates(corpus, tmp_path):
    """Exception types outside policy.restartable must not be absorbed."""
    e = LDAEngine(corpus, _cfg(), backend="single",
                  checkpoint_dir=str(tmp_path))
    plan = chaos.FaultPlan(raise_at_steps=(2,),
                           exc_factory=lambda m: KeyboardInterrupt(m))
    with chaos.active(plan), pytest.raises(KeyboardInterrupt):
        e.fit(6, supervise=_policy())


def test_invariant_violation_is_restartable(corpus, tmp_path):
    """A tripped invariant restarts from the newest checkpoint (the state
    is presumed poisoned) and still converges bitwise."""
    cfg = _cfg()
    ref = _ref(corpus, cfg, 8)
    e = LDAEngine(corpus, cfg, backend="single", checkpoint_dir=str(tmp_path))
    plan = chaos.FaultPlan(
        raise_at_steps=(5,),
        exc_factory=lambda m: invariants.InvariantViolation(
            "injected", "chaos hook", m))
    with chaos.active(plan):
        hist = e.fit(8, supervise=_policy())
    rep = hist["restart_report"]
    assert rep.restarts == 1
    assert "InvariantViolation" in rep.faults[0]
    assert _same(ref, e.host_payload())


# -- transient faults absorbed in place (no restart) ------------------------

def test_prefetch_io_fault_retried_in_place(corpus, tmp_path):
    """One failing load attempt of a PREFETCHED shard stays below the
    prefetcher's retry budget: absorbed on the worker thread."""
    cfg = _cfg(corpus_residency="streamed", stream_shards=4)
    ref = _ref(corpus, cfg, 6)
    e = LDAEngine(corpus, cfg, backend="single", checkpoint_dir=str(tmp_path))
    with chaos.active(chaos.FaultPlan(io_fault_shards=(1,),
                                      io_fault_attempts=1)):
        hist = e.fit(6, supervise=_policy())
    assert hist["restart_report"].restarts == 0
    assert _same(ref, e.host_payload())


def test_prefetch_io_fault_inline_restarts(corpus, tmp_path):
    """Shard 0 loads INLINE (it is the epoch's first 'current' shard, not
    prefetched), so its I/O fault skips the worker-thread retry and must
    go through the supervisor."""
    cfg = _cfg(corpus_residency="streamed", stream_shards=4)
    ref = _ref(corpus, cfg, 6)
    e = LDAEngine(corpus, cfg, backend="single", checkpoint_dir=str(tmp_path))
    with chaos.active(chaos.FaultPlan(io_fault_shards=(0,),
                                      io_fault_attempts=1)):
        hist = e.fit(6, supervise=_policy())
    rep = hist["restart_report"]
    assert rep.restarts == 1 and "OSError" in rep.faults[0]
    assert _same(ref, e.host_payload())


def test_corrupt_prefetched_shard_retried_in_place(corpus, tmp_path):
    """A bit flip in a prefetched shard's buffer trips the crc32 check ON
    THE WORKER THREAD; the retry reloads clean bytes — no restart."""
    cfg = _cfg(corpus_residency="streamed", stream_shards=4)
    ref = _ref(corpus, cfg, 6)
    e = LDAEngine(corpus, cfg, backend="single", checkpoint_dir=str(tmp_path))
    with chaos.active(chaos.FaultPlan(corrupt_shards=(2,),
                                      corrupt_attempts=1)):
        hist = e.fit(6, supervise=_policy())
    assert hist["restart_report"].restarts == 0
    assert _same(ref, e.host_payload())


def test_corrupt_inline_shard_restarts(corpus, tmp_path):
    cfg = _cfg(corpus_residency="streamed", stream_shards=4)
    ref = _ref(corpus, cfg, 6)
    e = LDAEngine(corpus, cfg, backend="single", checkpoint_dir=str(tmp_path))
    with chaos.active(chaos.FaultPlan(corrupt_shards=(0,),
                                      corrupt_attempts=1)):
        hist = e.fit(6, supervise=_policy())
    rep = hist["restart_report"]
    assert rep.restarts == 1 and "crc32" in rep.faults[0]
    assert _same(ref, e.host_payload())


# -- disk-native storage faults (DESIGN.md SS14) ----------------------------

def _disk_cfg(corpus, tmp_path, **kw):
    from repro.lda.corpus import shard_stream
    store = shard_stream(corpus, 4, multiple=256).to_store(
        str(tmp_path / "store"))
    return _cfg(corpus_residency="disk", corpus_path=store.path, **kw)


def test_disk_io_fault_retried_in_place(corpus, tmp_path):
    """A transient read fault in the FILE layer (CorpusStore.read_shard,
    prefetched shard) stays below the prefetcher's retry budget:
    absorbed on the worker thread, zero restarts, bitwise output."""
    cfg = _disk_cfg(corpus, tmp_path)
    ref = _ref(corpus, _cfg(), 6)
    e = LDAEngine(None, cfg, backend="single",
                  checkpoint_dir=str(tmp_path / "ck"))
    with chaos.active(chaos.FaultPlan(io_fault_shards=(1,),
                                      io_fault_attempts=1)):
        hist = e.fit(6, supervise=_policy())
    assert hist["restart_report"].restarts == 0
    assert _same(ref, e.host_payload())


def test_disk_io_fault_persistent_escalates_to_restart(corpus, tmp_path):
    """A PERSISTENT read fault outlives every in-place retry: the
    supervisor restarts from the newest checkpoint and the run still
    converges bitwise (5 failing attempts exhaust one 3-attempt retry
    round — restart — then fail 2 of the next round's 3 and clear)."""
    cfg = _disk_cfg(corpus, tmp_path)
    ref = _ref(corpus, _cfg(), 6)
    e = LDAEngine(None, cfg, backend="single",
                  checkpoint_dir=str(tmp_path / "ck"))
    with chaos.active(chaos.FaultPlan(io_fault_shards=(1,),
                                      io_fault_attempts=5)):
        hist = e.fit(6, supervise=_policy())
    rep = hist["restart_report"]
    assert rep.restarts == 1 and "OSError" in rep.faults[0]
    assert _same(ref, e.host_payload())


def test_disk_corrupt_shard_crc_retried_in_place(corpus, tmp_path):
    """A bit flip between the file read and the device put trips the
    crc32 self-check inside read_shard ON THE WORKER THREAD; the retry
    reloads clean bytes from disk — no restart."""
    cfg = _disk_cfg(corpus, tmp_path)
    ref = _ref(corpus, _cfg(), 6)
    e = LDAEngine(None, cfg, backend="single",
                  checkpoint_dir=str(tmp_path / "ck"))
    with chaos.active(chaos.FaultPlan(corrupt_shards=(2,),
                                      corrupt_attempts=1)):
        hist = e.fit(6, supervise=_policy())
    assert hist["restart_report"].restarts == 0
    assert _same(ref, e.host_payload())


def test_disk_corrupt_inline_shard_restarts(corpus, tmp_path):
    """Shard 0 loads INLINE (the epoch's first 'current' shard), so its
    crc failure skips the worker-thread retry and goes through the
    supervisor as a restartable ShardCorruptionError."""
    cfg = _disk_cfg(corpus, tmp_path)
    ref = _ref(corpus, _cfg(), 6)
    e = LDAEngine(None, cfg, backend="single",
                  checkpoint_dir=str(tmp_path / "ck"))
    with chaos.active(chaos.FaultPlan(corrupt_shards=(0,),
                                      corrupt_attempts=1)):
        hist = e.fit(6, supervise=_policy())
    rep = hist["restart_report"]
    assert rep.restarts == 1 and "crc32" in rep.faults[0]
    assert _same(ref, e.host_payload())


def test_disk_mid_epoch_kill_shardwise_bitwise(corpus, tmp_path):
    """Killed with an epoch open while training FROM DISK with paged W:
    the newest checkpoint is a mid-epoch stream payload with a manifest-
    relative cursor; resume re-pages and continues bit-identically."""
    cfg = _disk_cfg(corpus, tmp_path)
    ref = _ref(corpus, _cfg(), 8)
    e = LDAEngine(None, cfg, backend="single",
                  checkpoint_dir=str(tmp_path / "ck"))
    pol = _policy(checkpoint_shards=1)
    with chaos.active(chaos.FaultPlan(raise_at_shards=((5, 2),))):
        hist = e.fit(8, supervise=pol)
    rep = hist["restart_report"]
    assert rep.restarts == 1
    assert rep.resumed_from == [5]      # restored INTO the open epoch 5
    assert _same(ref, e.host_payload())


def test_disk_hybrid_mid_epoch_kill_shardwise_bitwise(corpus, tmp_path):
    cfg = _disk_cfg(corpus, tmp_path, format="hybrid")
    ref = _ref(corpus, _cfg(format="hybrid"), 8)
    e = LDAEngine(None, cfg, backend="single",
                  checkpoint_dir=str(tmp_path / "ck"))
    with chaos.active(chaos.FaultPlan(raise_at_shards=((5, 2),))):
        hist = e.fit(8, supervise=_policy(checkpoint_shards=1))
    assert hist["restart_report"].restarts == 1
    assert _same(ref, e.host_payload())


# -- graceful degradation ---------------------------------------------------

def test_oom_degrades_resident_to_streamed(corpus, tmp_path):
    """Injected RESOURCE_EXHAUSTED on the resident path: ONE degradation
    to streamed residency (with a warning), then bitwise convergence —
    streamed == resident is the PR5 bit-equality contract."""
    cfg = _cfg(corpus_residency="full", stream_shards=4)
    ref = _ref(corpus, cfg, 8)
    e = LDAEngine(corpus, cfg, backend="single", checkpoint_dir=str(tmp_path))
    with chaos.active(chaos.FaultPlan(oom_at_steps=(5,))), \
            pytest.warns(RuntimeWarning, match="streamed"):
        hist = e.fit(8, supervise=_policy())
    rep = hist["restart_report"]
    assert rep.degraded_to_streamed and rep.restarts == 1
    assert "RESOURCE_EXHAUSTED" in rep.faults[0]
    assert e.config.corpus_residency == "streamed"
    assert e.trainer.residency == "streamed"
    assert _same(ref, e.host_payload())


def test_second_oom_streamed_propagates(corpus, tmp_path):
    """Degradation happens ONCE: an OOM while already streamed is not
    absorbed forever — the budget (max_restarts) still bounds it."""
    cfg = _cfg(corpus_residency="streamed", stream_shards=4)
    e = LDAEngine(corpus, cfg, backend="single", checkpoint_dir=str(tmp_path))
    plan = chaos.FaultPlan(oom_at_steps=(2,), repeat=True)
    with chaos.active(plan), pytest.raises(chaos.SimulatedOOM):
        e.fit(6, supervise=_policy(max_restarts=1))


# -- straggler detection ----------------------------------------------------

def test_slow_step_flagged_as_straggler(corpus, tmp_path):
    cfg = _cfg(eval_every=1)        # chunk == 1 step → per-step timing
    e = LDAEngine(corpus, cfg, backend="single", checkpoint_dir=str(tmp_path))
    plan = chaos.FaultPlan(slow_steps={14: 0.5})
    with chaos.active(plan):
        hist = e.fit(16, supervise=_policy(straggler_window=16,
                                           straggler_z=4.0))
    rep = hist["restart_report"]
    assert rep.restarts == 0
    assert 15 in rep.straggler_steps    # on_chunk reports the POST-step it
    assert rep.timer_summary["n"] >= 16


# -- invariants + selfcheck -------------------------------------------------

def test_selfcheck_clean_runs(corpus, tmp_path):
    for cfg in (_cfg(selfcheck=True),
                _cfg(selfcheck=True, format="hybrid"),
                _cfg(selfcheck=True, corpus_residency="streamed",
                     stream_shards=4)):
        e = LDAEngine(corpus, cfg, backend="single")
        e.fit(4)                     # no InvariantViolation on a clean run
        assert int(e.iteration) == 4


def test_invariants_catch_bad_counts():
    D = np.full((3, 4), 2, np.int32)
    W = np.full((5, 4), 2, np.int32)        # sums differ: 24 vs 40
    with pytest.raises(invariants.InvariantViolation, match="conserv"):
        invariants.check_dense_counts(D, W, n_tokens=24, where="unit")
    with pytest.raises(invariants.InvariantViolation, match="negative"):
        invariants.check_dense_counts(np.array([[-1, 25]], np.int32),
                                      np.full((3, 2), 4, np.int32),
                                      n_tokens=24, where="unit")
    ok = np.full((6, 4), 1, np.int32)
    invariants.check_dense_counts(ok, ok, ok.sum(axis=0), n_tokens=24,
                                  where="unit")
    with pytest.raises(invariants.InvariantViolation, match="colsum"):
        invariants.check_dense_counts(ok, ok, ok.sum(axis=0) + 1,
                                      n_tokens=24, where="unit")


def test_invariants_delta_conservation():
    dD = np.array([[1, -1], [0, 0]], np.int32)
    invariants.check_delta_conservation(dD, dD, where="unit")
    with pytest.raises(invariants.InvariantViolation):
        invariants.check_delta_conservation(
            dD, np.array([[1, 0], [0, 0]], np.int32), where="unit")


def test_invariants_theta():
    invariants.check_theta(np.array([[0.5, 0.5]]), where="unit")
    with pytest.raises(invariants.InvariantViolation, match="finite"):
        invariants.check_theta(np.array([[np.nan, 1.0]]), where="unit")


# -- prefetcher unit tests --------------------------------------------------

def test_prefetcher_close_suppresses_pending_failure():
    """Teardown of an already-failed pipeline must not raise again — the
    failure belongs to take(), inside the loop, where a supervisor can
    act on it."""
    p = _Prefetcher(retries=0)

    def boom():
        raise OSError("pending failure")

    p.submit(boom)
    time.sleep(0.05)
    p.close()                        # no raise


def test_prefetcher_take_propagates_failure():
    p = _Prefetcher(retries=0)

    def boom():
        raise OSError("surfaced at take")

    p.submit(boom)
    with pytest.raises(OSError, match="surfaced"):
        p.take()
    p.close()


def test_prefetcher_retries_transient_failure():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise OSError("transient")
        return 7

    p = _Prefetcher(retries=2, backoff_s=0.0)
    p.submit(flaky)
    assert p.take() == 7 and calls["n"] == 3
    p.close()


def test_prefetcher_watchdog_times_out():
    p = _Prefetcher(deadline_s=0.05)
    p.submit(time.sleep, 5.0)
    with pytest.raises(PrefetchTimeout, match="watchdog"):
        p.take()
    p.close()


def test_watchdog_config_reaches_pipeline(corpus):
    cfg = _cfg(corpus_residency="streamed", stream_shards=4,
               stream_watchdog_seconds=30.0)
    e = LDAEngine(corpus, cfg, backend="single")
    assert e.trainer.fused_pipeline()._prefetch.deadline_s == 30.0


# -- residency warning ------------------------------------------------------

def test_resolve_residency_warns_once_without_memstats(monkeypatch):
    class _Dev:
        def memory_stats(self):
            raise RuntimeError("backend reports no memory stats")

    monkeypatch.setattr(lda_step, "_MEMSTATS_WARNED", False)
    cfg = _cfg(corpus_residency="auto")
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        assert lda_step.resolve_residency(cfg, 4096, device=_Dev()) \
            == ("full", 1)
        lda_step.resolve_residency(cfg, 4096, device=_Dev())
    hits = [w for w in rec if issubclass(w.category, RuntimeWarning)]
    assert len(hits) == 1            # one warning per process, not per call
    assert "device_budget_bytes" in str(hits[0].message)


# -- policy / classifier units ----------------------------------------------

def test_supervise_policy_validation():
    for bad in (dict(checkpoint_every=0), dict(checkpoint_shards=0),
                dict(max_restarts=-1), dict(backoff_base=-1.0)):
        with pytest.raises(ValueError):
            SupervisePolicy(**bad)


def test_backoff_delay_schedule():
    pol = SupervisePolicy(backoff_base=0.1, backoff_factor=2.0,
                          backoff_max=0.5)
    assert backoff_delay(pol, 0) == 0.0
    assert backoff_delay(pol, 1) == pytest.approx(0.1)
    assert backoff_delay(pol, 2) == pytest.approx(0.2)
    assert backoff_delay(pol, 3) == pytest.approx(0.4)
    assert backoff_delay(pol, 5) == 0.5          # capped


def test_is_oom_error_classifier():
    assert is_oom_error(chaos.SimulatedOOM("unit"))
    assert is_oom_error(RuntimeError("RESOURCE_EXHAUSTED: out of memory "
                                     "while trying to allocate"))
    assert is_oom_error(RuntimeError("CUDA error: out of memory"))
    assert not is_oom_error(ValueError("shape mismatch"))


def test_chaos_hooks_noop_when_unarmed():
    chaos.clear()
    assert not chaos.armed()
    chaos.step_range(0, 100)
    chaos.shard_event(0, 0)
    chaos.io_fault(0)
    arrays = (np.arange(4),)
    assert chaos.corrupt_arrays(0, arrays) is arrays


def test_fault_plan_fires_once_by_default():
    plan = chaos.FaultPlan(raise_at_steps=(3,))
    with chaos.active(plan):
        with pytest.raises(chaos.InjectedFault):
            chaos.step_range(0, 10)
        chaos.step_range(0, 10)      # second pass: already fired
    assert not chaos.armed()         # active() cleared the plan


# -- forged multi-device supervised recovery --------------------------------

@pytest.mark.slow
def test_distributed_supervised_recovery_bitwise(tmp_path):
    """8 forged CPU devices: a supervised distributed fit killed at step 6
    restores from its canonical checkpoint and converges bitwise with the
    uninterrupted distributed run (elastic canonical payloads)."""
    body = f"""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, "src")
    import numpy as np
    from repro.lda.api import LDAEngine, SupervisePolicy
    from repro.lda.corpus import synthetic_lda_corpus, relabel_by_frequency
    from repro.lda.model import LDAConfig
    from repro.runtime import chaos

    corpus = synthetic_lda_corpus(7, n_docs=50, n_words=60, n_topics=6,
                                  mean_doc_len=25)
    corpus, _ = relabel_by_frequency(corpus)
    cfg = LDAConfig(n_topics=8, tile_size=256, eval_every=4, seed=3)

    ref = LDAEngine(corpus, cfg, backend="distributed", pad_multiple=256)
    assert ref.backend_name == "distributed"
    ref.fit(10)
    want = ref.host_payload()

    eng = LDAEngine(corpus, cfg, backend="distributed", pad_multiple=256,
                    checkpoint_dir={str(tmp_path)!r})
    pol = SupervisePolicy(checkpoint_every=3, backoff_base=0.0)
    with chaos.active(chaos.FaultPlan(raise_at_steps=(6,))):
        hist = eng.fit(10, supervise=pol)
    rep = hist["restart_report"]
    assert rep.restarts == 1, rep
    got = eng.host_payload()
    for k in ("topics_global", "key", "iteration"):
        assert np.array_equal(np.asarray(want[k]), np.asarray(got[k])), k
    print("OK", rep.restarts)
    """
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(body)],
        capture_output=True, text=True, timeout=900, cwd=".")
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "OK" in proc.stdout
