"""tools/check_bench.py — the CI perf-regression gate.

Pins: committed results stay green; an injected out-of-tolerance metric
turns the check red; schema drift (missing/mistyped keys, empty cell
lists, undocumented files) fails; the dry-run mode skips metric gates
but still enforces schema.
"""

import copy
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tools"))
import check_bench  # noqa: E402

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def _load(name):
    return json.load(open(os.path.join(RESULTS, name)))


def _write(tmp_path, name, doc):
    path = tmp_path / name
    path.write_text(json.dumps(doc))
    return str(path)


def _committed():
    return sorted(f for f in os.listdir(RESULTS)
                  if f.startswith("BENCH_") and f.endswith(".json")
                  and f not in check_bench.SCHEMA_ALIASES)


def test_committed_results_pass():
    files = [os.path.join(RESULTS, f) for f in _committed()]
    assert files, "no committed BENCH files?"
    assert check_bench.main(files) == 0


def test_every_committed_file_has_schema_and_gates():
    for name in _committed():
        assert name in check_bench.SCHEMAS, name
        assert name in check_bench.GATES, name


@pytest.mark.parametrize("name,mutate", [
    ("BENCH_fused_step.json",
     lambda d: d.update(hybrid_slowdown_factor=1.9)),
    ("BENCH_fused_step.json",
     lambda d: d.update(host_syncs_in_scanned_region=3)),
    ("BENCH_fused_step.json", lambda d: d.update(speedup=1.2)),
    ("BENCH_balance.json",
     lambda d: d["throughput"].update(tiled_over_untiled=0.7)),
    ("BENCH_balance.json", lambda d: [
        row.update(imbalance=2.5) for row in d["schemes"]
        if row["scheme"] == "token_tiles"]),
    ("BENCH_hybrid_state.json", lambda d: [
        c.update(vs_dense_bytes=0.95) for c in d["cells"]]),
    ("BENCH_disk_streaming.json", lambda d: d.update(disk_bytes_ratio=0.8)),
    ("BENCH_disk_streaming.json",
     lambda d: d.update(disk_over_resident=0.4)),
    ("BENCH_disk_streaming.json",
     lambda d: d.update(paged_rows=d["vocab_rows"])),
    ("BENCH_disk_streaming.json",
     lambda d: d.update(bitwise_equal_to_resident=False)),
    ("BENCH_disk_streaming.json",
     lambda d: d.update(eval_equal_to_resident=False)),
    ("BENCH_disk_streaming.json", lambda d: d.update(n_shards=4)),
    ("BENCH_warp_sampler.json", lambda d: d.update(warp_over_exact=1.2)),
    ("BENCH_warp_sampler.json",
     lambda d: d.update(host_syncs_in_scanned_region=2)),
    ("BENCH_warp_sampler.json", lambda d: d.update(min_llpt_gap=0.5)),
    ("BENCH_warp_sampler.json", lambda d: d.update(n_topics=64)),
    ("BENCH_serve_service.json",
     lambda d: d.update(speedup_vs_batch=2.0)),
    ("BENCH_serve_service.json",
     lambda d: d["half_load"].update(p99_over_p50=9.0)),
    ("BENCH_serve_service.json", lambda d: d.update(cache_hit_rate=0.5)),
    ("BENCH_serve_service.json",
     lambda d: d["completion"].update(rate=0.97)),
    ("BENCH_serve_service.json",
     lambda d: d["quality"].update(delta_bits=0.4)),
    ("BENCH_ps_scaling.json", lambda d: d.update(owner_frac_at_max=0.5)),
    ("BENCH_ps_scaling.json",
     lambda d: d.update(staleness0_bitwise=False)),
    ("BENCH_ps_scaling.json", lambda d: d.update(max_workers=4)),
])
def test_injected_regression_fails(tmp_path, name, mutate):
    doc = copy.deepcopy(_load(name))
    mutate(doc)
    path = _write(tmp_path, name, doc)
    assert check_bench.main([path]) == 1


def test_within_tolerance_band_passes(tmp_path):
    """A bound breached by less than the band is tolerated (noise)."""
    doc = copy.deepcopy(_load("BENCH_fused_step.json"))
    doc["hybrid_slowdown_factor"] = 1.25 * 1.03     # inside the 5% band
    assert check_bench.main([_write(tmp_path, "BENCH_fused_step.json",
                                    doc)]) == 0
    doc["hybrid_slowdown_factor"] = 1.25 * 1.10     # outside
    assert check_bench.main([_write(tmp_path, "BENCH_fused_step.json",
                                    doc)]) == 1


@pytest.mark.parametrize("mutate", [
    lambda d: d.pop("speedup"),                       # missing key
    lambda d: d.update(speedup="fast"),               # wrong type
    lambda d: d["corpus"].pop("tokens"),              # nested missing
    lambda d: d.update(host_syncs_in_scanned_region=True),  # bool!=int
])
def test_schema_drift_fails(tmp_path, mutate):
    doc = copy.deepcopy(_load("BENCH_fused_step.json"))
    mutate(doc)
    assert check_bench.main([_write(tmp_path, "BENCH_fused_step.json",
                                    doc)]) == 1


def test_empty_cells_fail(tmp_path):
    doc = copy.deepcopy(_load("BENCH_hybrid_state.json"))
    doc["cells"] = []
    assert check_bench.main([_write(tmp_path, "BENCH_hybrid_state.json",
                                    doc)]) == 1


def test_undocumented_file_fails(tmp_path):
    assert check_bench.main([_write(tmp_path, "BENCH_mystery.json",
                                    {"x": 1})]) == 1


def test_dry_run_schema_only_mode(tmp_path):
    """The smoke artifact validates by schema with metric gates off —
    a dry run's numbers are meaningless, its SHAPE is not."""
    doc = copy.deepcopy(_load("BENCH_serve_lda.json"))
    doc["dry_run"] = True
    doc["best_docs_per_sec"] = 0.0            # would fail the metric gate
    path = _write(tmp_path, "BENCH_serve_lda_dryrun.json", doc)
    assert check_bench.main(["--dry-run-schema-only", path]) == 0
    doc.pop("cells")                          # but schema rot still fails
    path = _write(tmp_path, "BENCH_serve_lda_dryrun.json", doc)
    assert check_bench.main(["--dry-run-schema-only", path]) == 1


def test_disk_streaming_dryrun_alias(tmp_path):
    doc = copy.deepcopy(_load("BENCH_disk_streaming.json"))
    doc["dry_run"] = True
    doc["disk_bytes_ratio"] = 1.1             # would fail the metric gate
    path = _write(tmp_path, "BENCH_disk_streaming_dryrun.json", doc)
    assert check_bench.main(["--dry-run-schema-only", path]) == 0
    doc.pop("paged_rows")                     # schema rot still fails
    path = _write(tmp_path, "BENCH_disk_streaming_dryrun.json", doc)
    assert check_bench.main(["--dry-run-schema-only", path]) == 1


def test_ps_scaling_dryrun_alias(tmp_path):
    doc = copy.deepcopy(_load("BENCH_ps_scaling.json"))
    doc["dry_run"] = True
    doc["owner_frac_at_max"] = 0.9            # would fail the metric gate
    doc["max_workers"] = 2                    # dry runs stop at 2 workers
    path = _write(tmp_path, "BENCH_ps_scaling_dryrun.json", doc)
    assert check_bench.main(["--dry-run-schema-only", path]) == 0
    doc["cells"][0].pop("owner_frac")         # schema rot still fails
    path = _write(tmp_path, "BENCH_ps_scaling_dryrun.json", doc)
    assert check_bench.main(["--dry-run-schema-only", path]) == 1


def test_serve_service_dryrun_alias(tmp_path):
    doc = copy.deepcopy(_load("BENCH_serve_service.json"))
    doc["dry_run"] = True
    doc["speedup_vs_batch"] = 0.1             # would fail the metric gate
    path = _write(tmp_path, "BENCH_serve_service_dryrun.json", doc)
    assert check_bench.main(["--dry-run-schema-only", path]) == 0
    doc["serve"].pop("warmed_signatures")     # schema rot still fails
    path = _write(tmp_path, "BENCH_serve_service_dryrun.json", doc)
    assert check_bench.main(["--dry-run-schema-only", path]) == 1
