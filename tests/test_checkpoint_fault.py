"""Checkpoint manager + fault-tolerance loop tests (single device;
multi-device elastic restore is covered in tests/test_distributed.py)."""

import os

import numpy as np

from repro.checkpoint import CheckpointManager
from repro.lda.corpus import synthetic_lda_corpus, relabel_by_frequency
from repro.lda.model import LDAConfig
from repro.lda.trainer import LDATrainer
from repro.runtime.fault import StepTimer, run_with_restarts


def test_save_restore_roundtrip(tmp_path):
    m = CheckpointManager(str(tmp_path), keep_n=2)
    p = {"a": np.arange(10), "iteration": np.int64(3)}
    m.save(3, p)
    back = m.restore_latest()
    assert np.array_equal(back["a"], p["a"]) and int(back["iteration"]) == 3


def test_keep_n_and_latest(tmp_path):
    m = CheckpointManager(str(tmp_path), keep_n=2)
    for s in (1, 2, 3, 4):
        m.save(s, {"x": np.full(3, s), "iteration": np.int64(s)})
    assert m.all_steps() == [3, 4]
    assert int(m.restore_latest()["iteration"]) == 4


def test_corrupt_checkpoint_skipped(tmp_path):
    m = CheckpointManager(str(tmp_path), keep_n=5)
    m.save(1, {"x": np.arange(4), "iteration": np.int64(1)})
    m.save(2, {"x": np.arange(4), "iteration": np.int64(2)})
    # tear the newest file
    path = os.path.join(str(tmp_path), "step_00000002.npz")
    with open(path, "r+b") as f:
        f.truncate(40)
    back = m.restore_latest()
    assert back is not None and int(back["iteration"]) == 1


def test_no_tmp_leftovers_visible(tmp_path):
    m = CheckpointManager(str(tmp_path))
    m.save(1, {"x": np.arange(4)})
    assert not [f for f in os.listdir(tmp_path) if f.startswith(".tmp")]


def test_step_timer_straggler_flag():
    t = StepTimer(window=20, z_threshold=4.0)
    flagged = [t.record(1.0 + 0.01 * (i % 3)) for i in range(15)]
    assert not any(flagged)
    assert t.record(5.0)        # 5x median → straggler


def test_run_with_restarts_resumes(tmp_path):
    """Injected failures at steps 7 and 13 → run completes with 2 restarts
    and the final state matches an uninterrupted run (ESCA is deterministic
    given (corpus, seed, iteration) since D/W are derived from topics)."""
    corpus = synthetic_lda_corpus(3, n_docs=40, n_words=60, n_topics=6,
                                  mean_doc_len=30)
    corpus, _ = relabel_by_frequency(corpus)
    cfg = LDAConfig(n_topics=8, tile_size=256, seed=11)

    def make_trainer():
        return LDATrainer(corpus, cfg, _from_engine=True)

    failures = {7, 13}
    seen = set()

    def fail_at(step):
        if step in failures and step not in seen:
            seen.add(step)
            return True
        return False

    m = CheckpointManager(str(tmp_path), keep_n=3)
    state, report = run_with_restarts(make_trainer, n_steps=20, manager=m,
                                      checkpoint_every=5, fail_at=fail_at)
    assert report.completed_steps == 20
    assert report.restarts == 2
    assert report.resumed_from == [5, 10]

    # uninterrupted reference
    tr = LDATrainer(corpus, cfg, _from_engine=True)
    ref = tr.init_state()
    for _ in range(20):
        ref, _ = tr.step(ref)
    assert np.array_equal(np.asarray(ref.topics), np.asarray(state.topics))
