"""CheckpointManager recovery under on-disk damage (DESIGN.md §11).

Complements tests/test_checkpoint_fault.py: these tests damage the files
themselves — truncation, torn zip containers, checksum mismatches, empty
directories — and pin that ``restore_latest`` walks back to the newest
VALID checkpoint (reporting every skip through ``log_fn``) instead of
crashing or silently restoring garbage.
"""

import os

import numpy as np

from repro.checkpoint import CheckpointManager
from repro.checkpoint.manager import _checksum


def _save_steps(m, steps):
    for s in steps:
        m.save(s, {"x": np.arange(8) + s, "iteration": np.int64(s)})


def _path(tmp_path, step):
    return os.path.join(str(tmp_path), f"step_{step:08d}.npz")


def test_empty_dir_restores_none(tmp_path):
    m = CheckpointManager(str(tmp_path))
    assert m.restore_latest() is None
    assert m.all_steps() == []


def test_truncated_newest_walks_back(tmp_path):
    m = CheckpointManager(str(tmp_path), keep_n=5)
    _save_steps(m, (1, 2, 3))
    with open(_path(tmp_path, 3), "r+b") as f:
        f.truncate(10)          # not even a zip header survives
    back = m.restore_latest()
    assert int(back["iteration"]) == 2


def test_torn_zip_walks_back(tmp_path):
    """A torn external copy: valid-looking prefix, missing central
    directory — np.load raises BadZipFile, restore must absorb it."""
    m = CheckpointManager(str(tmp_path), keep_n=5)
    _save_steps(m, (1, 2))
    p = _path(tmp_path, 2)
    size = os.path.getsize(p)
    with open(p, "r+b") as f:
        f.truncate(size // 2)
    assert int(m.restore_latest()["iteration"]) == 1


def test_checksum_mismatch_rejected(tmp_path):
    """A well-formed npz whose payload doesn't match its checksum (bit
    rot, partial overwrite) restores as None, not as wrong data."""
    m = CheckpointManager(str(tmp_path), keep_n=5)
    _save_steps(m, (1,))
    good = {"x": np.arange(8) + 2, "iteration": np.int64(2)}
    arrs = dict(good)
    arrs["__checksum__"] = np.frombuffer(
        _checksum({"x": np.zeros(8)}).encode(), dtype=np.uint8)
    np.savez(_path(tmp_path, 2), **arrs)
    assert m.restore(2) is None
    assert int(m.restore_latest()["iteration"]) == 1


def test_all_corrupt_restores_none(tmp_path):
    m = CheckpointManager(str(tmp_path), keep_n=5)
    _save_steps(m, (1, 2))
    for s in (1, 2):
        with open(_path(tmp_path, s), "r+b") as f:
            f.truncate(5)
    assert m.restore_latest() is None


def test_walk_back_reports_skips_via_log_fn(tmp_path):
    """The supervisor surfaces every skipped checkpoint — a walk-back is
    visible, not silent."""
    m = CheckpointManager(str(tmp_path), keep_n=5)
    _save_steps(m, (1, 2, 3))
    for s in (2, 3):
        with open(_path(tmp_path, s), "r+b") as f:
            f.truncate(12)
    lines = []
    back = m.restore_latest(log_fn=lines.append)
    assert int(back["iteration"]) == 1
    assert len(lines) == 2
    assert any("step 3" in ln for ln in lines)
    assert any("step 2" in ln for ln in lines)
    assert all("walking back" in ln for ln in lines)


def test_validate_gate_walks_back(tmp_path):
    """``validate`` rejects intact-but-unusable checkpoints (e.g. a
    mid-epoch stream payload whose shard grid no longer matches the
    CorpusStore manifest) the same way corruption does: walk back,
    report, never crash."""
    m = CheckpointManager(str(tmp_path), keep_n=5)
    m.save(1, {"x": np.arange(8), "iteration": np.int64(1)})
    m.save(2, {"x": np.arange(8), "iteration": np.int64(2),
               "stream_n_shards": np.int64(8)})
    lines = []

    def grid_ok(payload):
        return int(payload.get("stream_n_shards", 4)) == 4

    back = m.restore_latest(log_fn=lines.append, validate=grid_ok)
    assert int(back["iteration"]) == 1
    assert len(lines) == 1 and "semantic validation" in lines[0]
    # a validate that RAISES is treated as a rejection, not a crash
    def explode(payload):
        raise KeyError("stream_n_shards")
    assert m.restore_latest(validate=explode) is None
    # and with no validate the newest intact payload still wins
    assert int(m.restore_latest()["iteration"]) == 2


def test_save_survives_reopen(tmp_path):
    """save() fsyncs file AND directory; a fresh manager over the same
    directory (a restarted process) sees the same newest payload."""
    m = CheckpointManager(str(tmp_path), keep_n=2)
    _save_steps(m, (1, 2, 3))
    m2 = CheckpointManager(str(tmp_path), keep_n=2)
    assert m2.all_steps() == [2, 3]
    assert int(m2.restore_latest()["iteration"]) == 3


def test_orphan_tmp_swept_and_ignored(tmp_path):
    m = CheckpointManager(str(tmp_path))
    orphan = os.path.join(str(tmp_path), ".tmp-deadbeef")
    with open(orphan, "wb") as f:
        f.write(b"half a checkpoint")
    _save_steps(m, (1,))
    assert not os.path.exists(orphan)
    assert int(m.restore_latest()["iteration"]) == 1
