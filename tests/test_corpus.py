"""Corpus/indexing substrate tests: token-list layout, relabeling, inverted
index (Fig 5), chunking (§V-B), padding, balance metadata (§V-A analogue)."""

import numpy as np
from _hyp import given, settings, st

from repro.core import balance, inverted_index
from repro.lda.corpus import (from_documents, relabel_by_frequency,
                              chunk_documents, pad_corpus, zipf_corpus)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_from_documents_invariants(seed):
    rng = np.random.default_rng(seed)
    n_words = rng.integers(5, 50)
    docs = [rng.integers(0, n_words, rng.integers(1, 30)).tolist()
            for _ in range(rng.integers(1, 20))]
    c = from_documents(docs, n_words)
    c.validate()
    assert c.n_tokens == sum(len(d) for d in docs)
    # word-sorted T; multiset of (word,doc) pairs preserved
    got = sorted(zip(c.word_ids.tolist(), c.doc_ids.tolist()))
    want = sorted((w, i) for i, d in enumerate(docs) for w in d)
    assert got == want


def test_relabel_by_frequency_monotone(skewed_corpus):
    counts = skewed_corpus.word_token_counts
    assert np.all(np.diff(counts) <= 0)


def test_inverted_index_roundtrip(skewed_corpus):
    c = skewed_corpus
    # doc-major reorder then scatter back is the identity
    vals = np.arange(c.n_tokens, dtype=np.int64)
    dm = vals[c.inv_token_idx]
    seg = inverted_index.doc_segment_ids(c)
    assert len(seg) == c.n_tokens
    # every doc-major slot's doc id matches the token it points at
    assert np.array_equal(c.doc_ids[c.inv_token_idx], seg)
    back = np.zeros_like(vals)
    back[c.inv_token_idx] = dm
    assert np.array_equal(back, vals)


def test_reconstruct_d_rows_matches_scatter(skewed_corpus):
    import jax.numpy as jnp
    c = skewed_corpus
    K = 8
    rng = np.random.default_rng(0)
    topics = rng.integers(0, K, c.n_tokens).astype(np.int32)
    D_scatter = np.zeros((c.n_docs, K), np.int32)
    np.add.at(D_scatter, (c.doc_ids, topics), 1)
    D_inv = inverted_index.reconstruct_d_rows(
        jnp.asarray(topics), jnp.asarray(c.inv_token_idx),
        jnp.asarray(inverted_index.doc_segment_ids(c)), c.n_docs, K)
    assert np.array_equal(np.asarray(D_inv), D_scatter)


def test_chunk_documents_balanced(skewed_corpus):
    """§V-B: greedy chunking beats the paper's observed ≤5% imbalance."""
    c = skewed_corpus
    assign = chunk_documents(c, 4)
    loads = np.bincount(assign, weights=c.doc_lengths, minlength=4)
    assert loads.max() / loads.min() < 1.05


def test_pad_corpus_keeps_sort_and_mask(skewed_corpus):
    c = skewed_corpus
    padded, mask = pad_corpus(c, 512)
    assert padded.word_ids.shape[0] % 512 == 0
    assert np.all(np.diff(padded.word_ids) >= 0)
    assert mask.sum() == c.n_tokens


def test_to_store_from_store_roundtrip(skewed_corpus, tmp_path):
    """ShardedCorpus -> disk store -> ShardedCorpus is bitwise (the deep
    format/corruption matrix lives in tests/test_storage.py)."""
    from repro.lda.corpus import ShardedCorpus, shard_stream
    sc = shard_stream(skewed_corpus, 5, multiple=64)
    store = sc.to_store(str(tmp_path / "store"))
    assert store.n_shards == sc.n_shards
    back = ShardedCorpus.from_store(str(tmp_path / "store"))
    assert np.array_equal(back.word_ids, sc.word_ids)
    assert np.array_equal(back.doc_ids, sc.doc_ids)
    assert np.array_equal(back.mask, sc.mask)
    assert np.array_equal(back.first_word, sc.first_word)
    assert np.array_equal(back.last_word, sc.last_word)
    back.validate(deep=True)


def test_tile_plan_and_imbalance():
    """§V-A: token tiling reaches (near-)perfect balance; block-per-word on a
    power-law corpus does not (the paper's motivating observation)."""
    c = zipf_corpus(5, n_docs=200, n_words=500, exponent=1.5, mean_doc_len=60)
    c, _ = relabel_by_frequency(c)
    plan = balance.build_tiles(c, tile_size=256)
    assert plan.n_tiles == -(-c.n_tokens // 256)
    assert plan.max_tiles_per_word >= 2  # the head word dissects across tiles
    r_naive = balance.load_imbalance(c, "block_per_word", 16)
    r_dyn = balance.load_imbalance(c, "dynamic", 16)
    r_dis = balance.load_imbalance(c, "dynamic+dissect", 16, tile_size=256,
                                   dissect_threshold=500)
    r_tile = balance.load_imbalance(c, "token_tiles", 16, tile_size=256)
    assert r_naive["imbalance"] > r_dyn["imbalance"] >= r_dis["imbalance"] - 1e-9
    assert r_tile["imbalance"] < 1.2
    assert r_tile["imbalance"] <= r_naive["imbalance"]
