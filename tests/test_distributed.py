"""Multi-device LDA tests. These run in a subprocess so the forged device
count (XLA_FLAGS) never leaks into the rest of the suite."""

import subprocess
import sys
import textwrap

import pytest

_PRELUDE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import numpy as np, jax, jax.numpy as jnp
from repro.lda.corpus import synthetic_lda_corpus, relabel_by_frequency
from repro.lda.model import LDAConfig
from repro.lda.distributed import DistLDATrainer
from repro.core import llpt as llpt_mod

corpus = synthetic_lda_corpus(0, n_docs=80, n_words=100, n_topics=8,
                              mean_doc_len=50)
corpus, _ = relabel_by_frequency(corpus)
cfg = LDAConfig(n_topics=16, tile_size=512)

def global_llpt(tr, state):
    D, W = tr.gather_global(state)
    return float(llpt_mod.llpt(
        jnp.asarray(corpus.word_ids), jnp.asarray(corpus.doc_ids),
        jnp.ones(corpus.n_tokens, jnp.int32), jnp.asarray(D.astype(np.int32)),
        jnp.asarray(W.astype(np.int32)), alpha=cfg.alpha_, beta=cfg.beta))
"""


def _run(body: str):
    proc = subprocess.run(
        [sys.executable, "-c", _PRELUDE + textwrap.dedent(body)],
        capture_output=True, text=True, timeout=900, cwd=".")
    assert proc.returncode == 0, proc.stderr[-4000:]
    return proc.stdout


@pytest.mark.slow
def test_dist_converges_and_conserves_tokens():
    out = _run("""
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    tr = DistLDATrainer(corpus, cfg, mesh, pad_multiple=256, _from_engine=True)
    state = tr.init_state()
    ll0 = global_llpt(tr, state)
    for _ in range(12):
        state, stats = tr.step(state)
        D, W = tr.gather_global(state)
        assert D.sum() == corpus.n_tokens == W.sum()
    ll1 = global_llpt(tr, state)
    assert ll1 > ll0 + 0.1, (ll0, ll1)
    assert 0.0 < float(stats.frac_skipped) < 1.0
    print("OK", ll0, ll1)
    """)
    assert "OK" in out


@pytest.mark.slow
def test_run_fused_matches_stepwise():
    """The scanned run_fused (donated state, stacked stats) is bit-identical
    to calling step() the same number of times — the multi-device analogue
    of tests/test_fused_step.py's scan-vs-stepwise pin."""
    out = _run("""
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    tr = DistLDATrainer(corpus, cfg, mesh, pad_multiple=256, _from_engine=True)
    s_step = tr.init_state()
    for _ in range(4):
        s_step, last_stats = tr.step(s_step)
    s_scan, stats = tr.run_fused(tr.init_state(), 4)
    assert np.array_equal(np.asarray(s_scan.topics), np.asarray(s_step.topics))
    assert np.array_equal(np.asarray(s_scan.D), np.asarray(s_step.D))
    assert np.array_equal(np.asarray(s_scan.W), np.asarray(s_step.W))
    assert int(s_scan.iteration) == 4
    assert np.asarray(stats.frac_skipped).shape == (4,)
    assert float(stats.frac_skipped[-1]) == float(last_stats.frac_skipped)
    D, W = tr.gather_global(s_scan)
    assert D.sum() == corpus.n_tokens == W.sum()
    print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_multipod_mesh_axes():
    """(pod, data, model) mesh — the multi-pod collective path lowers+runs."""
    out = _run("""
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    tr = DistLDATrainer(corpus, cfg, mesh, pad_multiple=256, _from_engine=True)
    state = tr.init_state()
    for _ in range(4):
        state, stats = tr.step(state)
    D, W = tr.gather_global(state)
    assert D.sum() == corpus.n_tokens == W.sum()
    print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_model_axis_parity():
    """Topic-sharded sampling must be distribution-compatible with model=1:
    identical (corpus, seed) runs on (4,1) and (2,2) meshes converge to the
    same LLPT plateau and conserve counts."""
    out = _run("""
    res = {}
    for shape, names in (((4, 1), ("data", "model")),
                         ((2, 2), ("data", "model"))):
        mesh = jax.make_mesh(shape, names)
        tr = DistLDATrainer(corpus, cfg, mesh, pad_multiple=256, _from_engine=True)
        state = tr.init_state()
        for _ in range(15):
            state, _ = tr.step(state)
        res[shape] = global_llpt(tr, state)
    print("RES", res)
    vals = list(res.values())
    assert abs(vals[0] - vals[1]) < 0.15, res
    print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_elastic_restore_across_mesh_sizes():
    """Checkpoint on a 4-shard mesh, restore on a 2-shard mesh: counts are
    rebuilt for the new chunking and training continues (elastic scaling)."""
    out = _run("""
    mesh4 = jax.make_mesh((4, 2), ("data", "model"))
    tr4 = DistLDATrainer(corpus, cfg, mesh4, pad_multiple=256, _from_engine=True)
    s4 = tr4.init_state()
    for _ in range(5):
        s4, _ = tr4.step(s4)
    payload = tr4.host_payload(s4)
    D4, W4 = tr4.gather_global(s4)

    mesh2 = jax.make_mesh((2, 4), ("data", "model"))
    tr2 = DistLDATrainer(corpus, cfg, mesh2, pad_multiple=256, _from_engine=True)
    s2 = tr2.state_from_payload(payload)
    D2, W2 = tr2.gather_global(s2)
    # same global counts, different layout
    assert np.array_equal(D4, D2) and np.array_equal(W4, W2)
    assert int(s2.iteration) == 5
    before = global_llpt(tr2, s2)
    for _ in range(8):
        s2, _ = tr2.step(s2)
    after = global_llpt(tr2, s2)
    assert after > before - 0.02  # keeps converging (allow plateau noise)
    print("OK", before, after)
    """)
    assert "OK" in out


@pytest.mark.slow
def test_token_balanced_sharding_with_dissection():
    """balance="tiles": token-balanced shard assignment with word
    dissection. Dissected documents keep replicated D rows glued by the
    shared-row delta psum — the gathered global D must stay EXACTLY the
    histogram of the checkpoint topics, and training must converge like
    document chunking does."""
    out = _run("""
    from repro.lda.distributed import shard_corpus
    cfg_t = LDAConfig(n_topics=16, tile_size=512, balance="tiles")
    sc = shard_corpus(corpus, 4, pad_multiple=256, balance="tiles")
    tps = sc.tokens_per_shard
    assert tps.max() / tps.mean() <= 1.25, tps        # token-balanced
    assert sc.shared_rows is not None                 # docs were dissected

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    tr = DistLDATrainer(corpus, cfg_t, mesh, pad_multiple=256, _from_engine=True)
    state = tr.init_state()
    ll0 = global_llpt(tr, state)
    for _ in range(12):
        state, stats = tr.step(state)
        D, W = tr.gather_global(state)
        assert D.sum() == corpus.n_tokens == W.sum()
    # replica consistency: replicated rows must equal the global histogram
    payload = tr.host_payload(state)
    Dref = np.zeros((corpus.n_docs, 16), np.int64)
    np.add.at(Dref, (corpus.doc_ids, payload["topics_global"]), 1)
    D, W = tr.gather_global(state)
    assert np.array_equal(D, Dref), "dissected D replicas drifted"
    ll1 = global_llpt(tr, state)
    assert ll1 > ll0 + 0.1, (ll0, ll1)
    # scanned == stepwise, same as the doc-chunked path
    s_scan, _ = tr.run_fused(tr.init_state(), 4)
    s_step = tr.init_state()
    for _ in range(4):
        s_step, _ = tr.step(s_step)
    assert np.array_equal(np.asarray(s_scan.topics),
                          np.asarray(s_step.topics))
    assert np.array_equal(np.asarray(s_scan.D), np.asarray(s_step.D))
    # elastic restore onto a doc-chunked trainer: same global counts
    tr2 = DistLDATrainer(corpus, cfg, jax.make_mesh((2, 1),
                         ("data", "model")), pad_multiple=256, _from_engine=True)
    s2 = tr2.state_from_payload(payload)
    D2, W2 = tr2.gather_global(s2)
    assert np.array_equal(D2, D) and np.array_equal(W2, W)
    # hybrid + tiles is rejected with an actionable error (on the pure
    # data-parallel mesh hybrid otherwise supports)
    try:
        DistLDATrainer(corpus, LDAConfig(n_topics=16, format="hybrid",
                       balance="tiles"),
                       jax.make_mesh((4, 1), ("data", "model")),
                       pad_multiple=256, _from_engine=True)
        raise AssertionError("hybrid+tiles should be rejected")
    except ValueError as e:
        assert "tiles" in str(e)
    print("OK", ll0, ll1)
    """)
    assert "OK" in out


@pytest.mark.slow
def test_moe_parallel_paths_match_local():
    """a2a-EP (seq-sharded) and ep-policy (batch-sharded) MoE dispatch are
    numerically identical to the single-device path at lossless capacity."""
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, "src")
        import dataclasses, numpy as np, jax, jax.numpy as jnp
        from repro.configs import REGISTRY
        from repro.models.registry import get_model, reduced_config
        from repro.runtime.sharding import LogicalRules, use_rules
        cfg = reduced_config(REGISTRY["deepseek-moe-16b"],
                             capacity_factor=64.0)
        cfg = dataclasses.replace(cfg, param_dtype="float32")
        api = get_model(cfg)
        params = api.init(jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        B, S = 8, 32
        batch = {"inputs": jnp.asarray(
                     rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
                 "labels": jnp.asarray(
                     rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
                 "mask": jnp.ones((B, S), jnp.int32)}
        ref = float(jax.jit(api.loss)(params, batch))
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        for policy in ("tp", "ep"):
            rules = LogicalRules(mesh, policy=policy)
            def f(p, b):
                with use_rules(rules):
                    return api.loss(p, b)
            got = float(jax.jit(f)(params, batch))
            assert abs(got - ref) < 5e-3, (policy, got, ref)
        print("OK")
    """)], capture_output=True, text=True, timeout=900, cwd=".")
    assert proc.returncode == 0 and "OK" in proc.stdout, proc.stderr[-3000:]
