"""Fused-iteration pipeline tests (train/lda_step.py).

The load-bearing properties:
  1. The incremental −1/+1 delta update stays EXACTLY equal to the
     esca.update_counts full-rebuild oracle over many iterations, including
     padded/masked tokens (which must never move counts).
  2. fused_step reproduces LDATrainer.step's topics AND D/W counts
     bit-for-bit given the same key — for both phase-2 routings (the dense
     exact reference and the Pallas sample_fused kernel).
  3. run_fused (lax.scan) == repeated fused_step == the trainer loop, and
     chunk capacity is a pure performance knob (any capacity, same bits).
  4. The maintained Ŵ column sum never drifts from W.sum(axis=0).
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import esca
from repro.lda.model import LDAConfig
from repro.lda.trainer import LDATrainer
from repro.train.lda_step import plan_capacity

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# 1. delta update == full rebuild (property test, no hypothesis needed)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 7, 42])
def test_delta_update_matches_rebuild_oracle(seed):
    """Random topic trajectories: delta-applied counts == rebuilt counts."""
    rng = np.random.default_rng(seed)
    n, n_docs, n_words, K = 513, 20, 30, 9
    word_ids = jnp.asarray(np.sort(rng.integers(0, n_words, n)), jnp.int32)
    doc_ids = jnp.asarray(rng.integers(0, n_docs, n), jnp.int32)
    # ~10% pad tokens, interleaved to catch masked-token handling
    mask = jnp.asarray((rng.random(n) > 0.1).astype(np.int32))
    topics = jnp.asarray(rng.integers(0, K, n), jnp.int32)
    D, W = esca.update_counts(word_ids, doc_ids, topics, mask,
                              n_docs=n_docs, n_words=n_words, n_topics=K)
    colsum = jnp.sum(W, axis=0, dtype=jnp.int32)
    for it in range(5):
        # partial resample: most tokens keep their topic (the converged
        # regime the delta update is built for); pad tokens get new topics
        # too — they must still not move any count
        keep = rng.random(n) < 0.6
        new = np.where(keep, np.asarray(topics), rng.integers(0, K, n))
        new_topics = jnp.asarray(new, jnp.int32)
        D, W = esca.delta_update_counts(D, W, word_ids, doc_ids, topics,
                                        new_topics, mask)
        colsum = esca.delta_update_colsum(colsum, topics, new_topics, mask)
        topics = new_topics
        D_ref, W_ref = esca.update_counts(word_ids, doc_ids, topics, mask,
                                          n_docs=n_docs, n_words=n_words,
                                          n_topics=K)
        assert np.array_equal(np.asarray(D), np.asarray(D_ref)), it
        assert np.array_equal(np.asarray(W), np.asarray(W_ref)), it
        assert np.array_equal(np.asarray(colsum),
                              np.asarray(W_ref).sum(axis=0)), it


# ---------------------------------------------------------------------------
# 2./3. fused pipeline == reference trainer, bit for bit
# ---------------------------------------------------------------------------

def _reference_trajectory(corpus, cfg, n_iters):
    tr = LDATrainer(corpus, cfg, _from_engine=True)
    state = tr.init_state()
    traj = []
    for _ in range(n_iters):
        state, _ = tr.step(state)
        traj.append((np.asarray(state.topics), np.asarray(state.D),
                     np.asarray(state.W)))
    return tr, traj


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_fused_step_matches_trainer_bitwise(small_corpus, impl):
    cfg = LDAConfig(n_topics=16, tile_size=512, sampler="three_branch",
                    impl=impl)
    # reference uses the dense exact path regardless of impl
    _, traj = _reference_trajectory(
        small_corpus, LDAConfig(n_topics=16, tile_size=512,
                                sampler="three_branch"), 5)
    tr = LDATrainer(small_corpus, cfg, _from_engine=True)
    pipe = tr.fused_pipeline()
    fs = pipe.from_lda_state(tr.init_state())
    for i, (t_ref, d_ref, w_ref) in enumerate(traj):
        fs, stats, n_surv = pipe.step(fs)
        assert np.array_equal(np.asarray(fs.topics), t_ref), (impl, i)
        assert np.array_equal(np.asarray(fs.D), d_ref), (impl, i)
        assert np.array_equal(np.asarray(fs.W), w_ref), (impl, i)
        assert np.array_equal(np.asarray(fs.colsum), w_ref.sum(axis=0))
        assert 0 < int(n_surv) <= pipe.n_tokens


def test_run_fused_scan_equals_stepwise(small_corpus):
    cfg = LDAConfig(n_topics=16, tile_size=512, sampler="three_branch")
    tr = LDATrainer(small_corpus, cfg, _from_engine=True)
    pipe = tr.fused_pipeline()
    fs_scan, stats, n_surv = pipe.run_fused(
        pipe.from_lda_state(tr.init_state()), 5)
    assert np.asarray(n_surv).shape == (5,)
    assert np.asarray(stats.frac_skipped).shape == (5,)
    fs_step = pipe.from_lda_state(tr.init_state())
    for _ in range(5):
        fs_step, _, _ = pipe.step(fs_step)
    assert np.array_equal(np.asarray(fs_scan.topics),
                          np.asarray(fs_step.topics))
    assert np.array_equal(np.asarray(fs_scan.D), np.asarray(fs_step.D))
    assert np.array_equal(np.asarray(fs_scan.W), np.asarray(fs_step.W))


def test_capacity_is_a_pure_perf_knob(small_corpus):
    """Any survivor-chunk capacity gives identical bits."""
    cfg = LDAConfig(n_topics=16, tile_size=512, sampler="three_branch")
    outs = []
    for cap in (64, 300, 10 ** 6):
        tr = LDATrainer(small_corpus, LDAConfig(
            n_topics=16, tile_size=512, sampler="three_branch",
            survivor_capacity=cap), _from_engine=True)
        pipe = tr.fused_pipeline()
        fs, _, _ = pipe.run_fused(pipe.from_lda_state(tr.init_state()), 3,
                                  replan=False)
        outs.append(np.asarray(fs.topics))
    assert np.array_equal(outs[0], outs[1])
    assert np.array_equal(outs[1], outs[2])


def test_trainer_run_fused_end_to_end(small_corpus):
    """config.fused routes run() through the pipeline; LLPT still rises and
    the fused history matches the reference run's final state bitwise."""
    cfg = LDAConfig(n_topics=16, tile_size=512, sampler="three_branch",
                    eval_every=5)
    tr_ref = LDATrainer(small_corpus, cfg, _from_engine=True)
    s_ref = tr_ref.init_state()
    for _ in range(10):
        s_ref, _ = tr_ref.step(s_ref)

    tr_f = LDATrainer(small_corpus, LDAConfig(
        n_topics=16, tile_size=512, sampler="three_branch",
        eval_every=5, fused=True), _from_engine=True)
    s_f, hist = tr_f.run(10)
    assert np.array_equal(np.asarray(s_f.topics), np.asarray(s_ref.topics))
    assert np.array_equal(np.asarray(s_f.D), np.asarray(s_ref.D))
    assert len(hist["llpt"]) >= 2
    assert hist["llpt"][-1] > hist["llpt"][0] - 0.05  # converging, not noise


def test_run_fused_resume_hits_absolute_boundaries(small_corpus):
    """A resumed fused run (start iteration not on an eval boundary, odd
    n_iters) must still eval at the same ABSOLUTE iterations as run()."""
    cfg = LDAConfig(n_topics=16, tile_size=512, sampler="three_branch",
                    eval_every=5, fused=True)
    tr = LDATrainer(small_corpus, cfg, _from_engine=True)
    state = tr.init_state()
    for _ in range(3):                       # land on iteration 3
        state, _ = tr.step(state)
    state, hist = tr.run_fused(9, state=state)   # iterations 4..12
    assert int(state.iteration) == 12
    # evals at the absolute boundaries 5 and 10 (plus the first chunk)
    assert 5 in hist["iteration"] and 10 in hist["iteration"]


def test_plan_capacity_buckets():
    assert plan_capacity(0, 10 ** 6) == 2048           # floor
    assert plan_capacity(100_000, 10 ** 6) == 16384    # ~ema/8 -> next pow2
    assert plan_capacity(10 ** 9, 4096) == 4096        # clamped to corpus
