"""Hybrid sparse live-state tests (DESIGN.md SS5).

The load-bearing properties:
  1. Training on the hybrid state (packed-ELL D + HybridW) through the
     fused pipeline is BIT-EXACT vs the dense reference trainer on the
     planted synthetic corpus — topics, D, W, and colsum — for both
     phase-2 routings (dense exact reference and the Pallas kernel).
  2. The overflow policy: capacities are row-nnz upper bounds, so the
     runtime overflow tripwire stays 0; a pinned d_capacity below the
     bound fails at build time with an actionable ValueError.
  3. dense <-> hybrid conversions round-trip exactly, and the measured
     live-state nbytes() beats dense on a Zipf corpus at large K.
  4. The O(L) tail sampler (tail_sampler="sparse") keeps the packed counts
     exactly consistent with the topics and still converges (it draws from
     the same distribution, not the same bits — the documented trade).
  5. Checkpoints stay format-agnostic: topics+rng payloads restore into
     either layout.
"""

import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax

from repro.core import esca
from repro.lda.model import HybridLayout, LDAConfig
from repro.lda.trainer import LDATrainer

jax.config.update("jax_platform_name", "cpu")


def _reference_trajectory(corpus, cfg, n_iters):
    tr = LDATrainer(corpus, cfg, _from_engine=True)
    state = tr.init_state()
    traj = []
    for _ in range(n_iters):
        state, _ = tr.step(state)
        traj.append((np.asarray(state.topics), np.asarray(state.D),
                     np.asarray(state.W)))
    return traj


# ---------------------------------------------------------------------------
# 1. bit-exactness vs the dense reference trainer
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_hybrid_fused_matches_dense_reference_bitwise(small_corpus, impl):
    traj = _reference_trajectory(
        small_corpus, LDAConfig(n_topics=16, tile_size=512,
                                sampler="three_branch"), 5)
    tr = LDATrainer(small_corpus, LDAConfig(
        n_topics=16, tile_size=512, sampler="three_branch",
        format="hybrid", impl=impl), _from_engine=True)
    pipe = tr.fused_pipeline()
    hs = pipe.from_lda_state(tr.init_state())
    for i, (t_ref, d_ref, w_ref) in enumerate(traj):
        hs, stats, n_surv = pipe.step(hs)
        dense = pipe.to_lda_state(hs)
        assert np.array_equal(np.asarray(hs.topics), t_ref), (impl, i)
        assert np.array_equal(np.asarray(dense.D), d_ref), (impl, i)
        assert np.array_equal(np.asarray(dense.W), w_ref), (impl, i)
        assert np.array_equal(np.asarray(hs.colsum), w_ref.sum(axis=0))
        assert int(hs.overflow) == 0, (impl, i)
        assert 0 < int(n_surv) <= pipe.n_tokens


def test_hybrid_run_fused_scan_equals_stepwise(small_corpus):
    cfg = LDAConfig(n_topics=16, tile_size=512, format="hybrid")
    tr = LDATrainer(small_corpus, cfg, _from_engine=True)
    pipe = tr.fused_pipeline()
    hs_scan, stats, n_surv = pipe.run_fused(
        pipe.from_lda_state(tr.init_state()), 5)
    assert np.asarray(n_surv).shape == (5,)
    hs_step = pipe.from_lda_state(tr.init_state())
    for _ in range(5):
        hs_step, _, _ = pipe.step(hs_step)
    assert np.array_equal(np.asarray(hs_scan.topics),
                          np.asarray(hs_step.topics))
    d_scan, d_step = pipe.to_lda_state(hs_scan), pipe.to_lda_state(hs_step)
    assert np.array_equal(np.asarray(d_scan.D), np.asarray(d_step.D))
    assert np.array_equal(np.asarray(d_scan.W), np.asarray(d_step.W))


def test_trainer_run_hybrid_end_to_end(small_corpus):
    """config.format='hybrid' routes run() through the hybrid pipeline and
    matches the dense reference run bitwise; LLPT still rises."""
    tr_ref = LDATrainer(small_corpus, LDAConfig(
        n_topics=16, tile_size=512, eval_every=5), _from_engine=True)
    s_ref = tr_ref.init_state()
    for _ in range(10):
        s_ref, _ = tr_ref.step(s_ref)

    tr_h = LDATrainer(small_corpus, LDAConfig(
        n_topics=16, tile_size=512, eval_every=5, format="hybrid"), _from_engine=True)
    s_h, hist = tr_h.run(10)
    assert np.array_equal(np.asarray(s_h.topics), np.asarray(s_ref.topics))
    assert np.array_equal(np.asarray(s_h.D), np.asarray(s_ref.D))
    assert np.array_equal(np.asarray(s_h.W), np.asarray(s_ref.W))
    assert len(hist["llpt"]) >= 2
    assert hist["llpt"][-1] > hist["llpt"][0] - 0.05


# ---------------------------------------------------------------------------
# 2. overflow policy
# ---------------------------------------------------------------------------

def test_pinned_d_capacity_below_bound_raises(small_corpus):
    cfg = LDAConfig(n_topics=16, tile_size=512, format="hybrid",
                    d_capacity=2)
    with pytest.raises(ValueError, match="d_capacity"):
        LDATrainer(small_corpus, cfg, _from_engine=True).fused_pipeline()


def test_unrelabeled_corpus_raises():
    from repro.lda.corpus import synthetic_lda_corpus
    c = synthetic_lda_corpus(3, n_docs=30, n_words=50, n_topics=4,
                             mean_doc_len=30)
    # deliberately NOT relabeled; hybrid needs the frequency layout
    with pytest.raises(ValueError, match="relabel"):
        HybridLayout.build(c, LDAConfig(n_topics=8, format="hybrid"))


def test_format_knob_validation(small_corpus):
    with pytest.raises(ValueError, match="format"):
        LDATrainer(small_corpus, LDAConfig(n_topics=8, format="csr"), _from_engine=True)
    with pytest.raises(ValueError, match="tail_sampler"):
        LDATrainer(small_corpus, LDAConfig(n_topics=8,
                                           tail_sampler="magic"), _from_engine=True)


# ---------------------------------------------------------------------------
# 3. conversions + measured memory
# ---------------------------------------------------------------------------

def test_conversion_roundtrip(small_corpus):
    cfg = LDAConfig(n_topics=16, tile_size=512, format="hybrid")
    tr = LDATrainer(small_corpus, cfg, _from_engine=True)
    pipe = tr.fused_pipeline()
    state = tr.init_state()
    back = pipe.to_lda_state(pipe.from_lda_state(state))
    assert np.array_equal(np.asarray(back.topics), np.asarray(state.topics))
    assert np.array_equal(np.asarray(back.D), np.asarray(state.D))
    assert np.array_equal(np.asarray(back.W), np.asarray(state.W))


def test_hybrid_live_state_smaller_than_dense_on_zipf(skewed_corpus):
    """The Table-I direction on MEASURED buffers, not byte models."""
    k = 64
    cfg = LDAConfig(n_topics=k, tile_size=512, format="hybrid")
    tr = LDATrainer(skewed_corpus, cfg, _from_engine=True)
    state = tr.init_state()
    hybrid_bytes = tr.live_state_nbytes(state)
    dense_bytes = state.nbytes()
    assert hybrid_bytes < dense_bytes, (hybrid_bytes, dense_bytes)


# ---------------------------------------------------------------------------
# 4. the O(L) tail sampler
# ---------------------------------------------------------------------------

def test_sparse_tail_sampler_counts_consistent_and_converges(small_corpus):
    tr = LDATrainer(small_corpus, LDAConfig(
        n_topics=16, tile_size=512, format="hybrid",
        tail_sampler="sparse", eval_every=5), _from_engine=True)
    state, hist = tr.run(15)
    D_o, W_o = esca.update_counts(
        tr.word_ids, tr.doc_ids, state.topics, tr.mask,
        n_docs=tr.n_docs, n_words=tr.n_words, n_topics=16)
    assert np.array_equal(np.asarray(state.D), np.asarray(D_o))
    assert np.array_equal(np.asarray(state.W), np.asarray(W_o))
    assert hist["llpt"][-1] > hist["llpt"][0]


# ---------------------------------------------------------------------------
# 5. format-agnostic checkpoints
# ---------------------------------------------------------------------------

def test_checkpoint_payload_restores_into_either_format(small_corpus):
    cfg_h = LDAConfig(n_topics=16, tile_size=512, format="hybrid")
    tr_h = LDATrainer(small_corpus, cfg_h, _from_engine=True)
    pipe = tr_h.fused_pipeline()
    hs = pipe.from_lda_state(tr_h.init_state())
    for _ in range(3):
        hs, _, _ = pipe.step(hs)
    payload = hs.host_payload()
    assert set(payload) == {"topics", "key", "iteration"}  # still topics+rng

    # dense trainer restores and rebuilds dense counts
    tr_d = LDATrainer(small_corpus, LDAConfig(n_topics=16, tile_size=512), _from_engine=True)
    s_d = tr_d.state_from_payload(payload)
    ref = pipe.to_lda_state(hs)
    assert np.array_equal(np.asarray(s_d.D), np.asarray(ref.D))
    assert np.array_equal(np.asarray(s_d.W), np.asarray(ref.W))

    # hybrid trainer restores the same payload back into packed form
    s_h2 = pipe.from_lda_state(tr_h.state_from_payload(payload))
    assert np.array_equal(np.asarray(pipe.to_lda_state(s_h2).D),
                          np.asarray(ref.D))
    assert int(s_h2.iteration) == int(hs.iteration)


# ---------------------------------------------------------------------------
# 6. distributed hybrid (forged devices, subprocess like test_distributed)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_dist_hybrid_matches_dist_dense_bitwise():
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, "src")
        import numpy as np, jax
        from repro.lda.corpus import synthetic_lda_corpus, relabel_by_frequency
        from repro.lda.model import LDAConfig
        from repro.lda.distributed import DistLDATrainer

        corpus = synthetic_lda_corpus(0, n_docs=80, n_words=100, n_topics=8,
                                      mean_doc_len=50)
        corpus, _ = relabel_by_frequency(corpus)
        mesh = jax.make_mesh((4, 1), ("data", "model"))
        trd = DistLDATrainer(corpus, LDAConfig(n_topics=16, tile_size=512),
                             mesh, pad_multiple=256, _from_engine=True)
        trh = DistLDATrainer(corpus, LDAConfig(n_topics=16, tile_size=512,
                                               format="hybrid"),
                             mesh, pad_multiple=256, _from_engine=True)
        sd, sh = trd.init_state(), trh.init_state()
        for i in range(5):
            sd, _ = trd.step(sd)
            sh, _ = trh.step(sh)
            assert np.array_equal(np.asarray(sd.topics),
                                  np.asarray(sh.topics)), i
        Dd, Wd = trd.gather_global(sd)
        Dh, Wh = trh.gather_global(sh)
        assert np.array_equal(Dd, Dh) and np.array_equal(Wd, Wh)
        assert Dh.sum() == corpus.n_tokens == Wh.sum()
        assert int(sh.overflow) == 0          # packed tripwire stayed clean
        assert trh.state_nbytes(sh) < trd.state_nbytes(sd)
        s2, _ = trh.run_fused(trh.init_state(), 5)
        assert np.array_equal(np.asarray(s2.topics), np.asarray(sh.topics))
        # hybrid needs model axis 1
        try:
            DistLDATrainer(corpus, LDAConfig(n_topics=16, format="hybrid"),
                           jax.make_mesh((2, 2), ("data", "model")), _from_engine=True)
            raise SystemExit("expected ValueError")
        except ValueError:
            pass
        print("OK")
    """)], capture_output=True, text=True, timeout=900, cwd=".")
    assert proc.returncode == 0 and "OK" in proc.stdout, proc.stderr[-4000:]
