"""Pallas kernel sweeps: every kernel vs its ref.py oracle across
shapes/dtypes (interpret mode; the TPU target compiles the same code)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.sparse import pack_pairs
from repro.kernels import ref
from repro.kernels.histogram import histogram
from repro.kernels.sample_fused import sample_fused
from repro.kernels.sample_sparse import sample_sparse


@pytest.mark.parametrize("n,K", [(64, 32), (100, 64), (300, 130),
                                 (128, 512), (257, 1000), (16, 2048)])
def test_sample_fused_vs_ref(n, K):
    rng = np.random.default_rng(n * 1000 + K)
    d = (rng.integers(0, 50, (n, K)) * (rng.random((n, K)) < 0.1)).astype(np.int32)
    w = rng.random((n, K)).astype(np.float32) * 0.01
    u = rng.random(n).astype(np.float32)
    alpha = 50.0 / K
    t_k, m_k, s_k, q_k = sample_fused(
        jnp.asarray(u), jnp.asarray(d), jnp.asarray(w), alpha=alpha,
        interpret=True)
    t_r, m_r, s_r, q_r = ref.sample_fused_ref(
        jnp.asarray(u), jnp.asarray(d), jnp.asarray(w), alpha=alpha)
    np.testing.assert_allclose(m_k, m_r, rtol=1e-5)
    np.testing.assert_allclose(s_k, s_r, rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(q_k, q_r, rtol=1e-4, atol=1e-6)
    # float-associativity at CDF boundaries may flip a measure-zero set
    assert np.mean(np.asarray(t_k) != np.asarray(t_r)) < 2e-3


@pytest.mark.parametrize("tile_t,block_k", [(64, 128), (128, 512), (256, 256)])
def test_sample_fused_tiling_invariance(tile_t, block_k):
    """Output must not depend on the BlockSpec tiling (masses to fp tol)."""
    rng = np.random.default_rng(5)
    n, K = 200, 700
    d = (rng.integers(0, 50, (n, K)) * (rng.random((n, K)) < 0.2)).astype(np.int32)
    w = rng.random((n, K)).astype(np.float32) * 0.01
    u = rng.random(n).astype(np.float32)
    t1, m1, s1, q1 = sample_fused(jnp.asarray(u), jnp.asarray(d),
                                  jnp.asarray(w), alpha=0.1, tile_t=tile_t,
                                  block_k=block_k, interpret=True)
    t2, m2, s2, q2 = sample_fused(jnp.asarray(u), jnp.asarray(d),
                                  jnp.asarray(w), alpha=0.1, interpret=True)
    np.testing.assert_allclose(m1, m2, rtol=1e-5)
    np.testing.assert_allclose(s1, s2, rtol=1e-4, atol=1e-6)
    assert np.mean(np.asarray(t1) != np.asarray(t2)) < 2e-3


@pytest.mark.parametrize("n,L,K", [(100, 8, 64), (300, 16, 256),
                                   (513, 32, 1000), (64, 4, 33)])
def test_sample_sparse_vs_ref(n, L, K):
    rng = np.random.default_rng(n + L + K)
    idx = np.zeros((n, L), np.int32)
    val = np.zeros((n, L), np.int32)
    for i in range(n):
        nnz = rng.integers(0, L + 1)
        idx[i] = rng.choice(K, L, replace=False)
        val[i, :nnz] = rng.integers(1, 30, nnz)
    packed = pack_pairs(jnp.asarray(idx), jnp.asarray(val))
    W_row = rng.random(K).astype(np.float32) * 0.01
    w_at = jnp.asarray(W_row[idx])
    k1 = jnp.asarray(rng.integers(0, K, n).astype(np.int32))
    a1 = jnp.asarray(rng.random(n).astype(np.float32) * 0.02)
    b1 = jnp.asarray(rng.integers(0, 20, n).astype(np.float32))
    qp = jnp.asarray(rng.random(n).astype(np.float32) * 0.05)
    u = jnp.asarray(rng.random(n).astype(np.float32))
    alpha = 50.0 / K
    tk, nq_k, sp_k = sample_sparse(u, packed, w_at, k1, a1, b1, qp,
                                   alpha=alpha, interpret=True)
    tr_, nq_r, sp_r = ref.sample_sparse_ref(
        u, jnp.asarray(idx), jnp.asarray(val), w_at, k1, a1, b1, qp,
        alpha=alpha)
    np.testing.assert_allclose(sp_k, sp_r, rtol=1e-5, atol=1e-7)
    assert np.array_equal(np.asarray(nq_k), np.asarray(nq_r))
    assert np.mean(np.asarray(tk) != np.asarray(tr_)) < 2e-3


@pytest.mark.parametrize("n,R,K,rpt", [
    (2000, 50, 64, 32),      # narrow rows: pure MXU path
    (5000, 300, 130, 64),    # mixed
    (4096, 1000, 256, 16),   # wide rows: exercises the fallback scatter
    (777, 10, 33, 8),        # unaligned everything
])
def test_histogram_vs_ref(n, R, K, rpt):
    rng = np.random.default_rng(n + R)
    rows = np.sort(rng.integers(0, R, n)).astype(np.int32)
    topics = rng.integers(0, K, n).astype(np.int32)
    w = (rng.random(n) < 0.9).astype(np.int32)
    out = histogram(jnp.asarray(rows), jnp.asarray(topics), jnp.asarray(w),
                    n_rows=R, n_topics=K, tile_t=512, rows_per_tile=rpt,
                    interpret=True)
    want = ref.histogram_ref(jnp.asarray(rows), jnp.asarray(topics),
                             jnp.asarray(w), n_rows=R, n_topics=K)
    assert np.array_equal(np.asarray(out), np.asarray(want))


def test_pallas_update_counts_matches_esca(small_corpus):
    from repro.core import esca, inverted_index
    from repro.kernels import ops as kops
    c = small_corpus
    K = 16
    rng = np.random.default_rng(0)
    topics = jnp.asarray(rng.integers(0, K, c.n_tokens).astype(np.int32))
    mask = jnp.ones(c.n_tokens, jnp.int32)
    wi, di = jnp.asarray(c.word_ids), jnp.asarray(c.doc_ids)
    D0, W0 = esca.update_counts(wi, di, topics, mask, n_docs=c.n_docs,
                                n_words=c.n_words, n_topics=K)
    D1, W1 = kops.update_counts(
        wi, di, topics, mask, jnp.asarray(c.inv_token_idx),
        jnp.asarray(inverted_index.doc_segment_ids(c)),
        n_docs=c.n_docs, n_words=c.n_words, n_topics=K, interpret=True)
    assert np.array_equal(np.asarray(D0), np.asarray(D1))
    assert np.array_equal(np.asarray(W0), np.asarray(W1))


def test_pallas_trainer_e2e(small_corpus):
    """impl=pallas end-to-end: LLPT rises, same direction as the XLA path."""
    from repro.lda.model import LDAConfig
    from repro.lda.trainer import LDATrainer
    cfg = LDAConfig(n_topics=16, tile_size=512, impl="pallas")
    tr = LDATrainer(small_corpus, cfg, _from_engine=True)
    state = tr.init_state()
    llpt0 = tr.evaluate(state)
    for _ in range(8):
        state, stats = tr.step(state)
    llpt1 = tr.evaluate(state)
    assert llpt1 > llpt0 + 0.05 and not np.isnan(llpt1)


def test_sparse_d_sampling_path_matches_reference(small_corpus):
    """ops.sample_tokens_sparse_d (packed-ELL D rows, O(L) per token) draws
    from the same distribution as the dense reference sampler."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core import esca, three_branch
    from repro.core.sparse import build_sparse_rows
    from repro.kernels import ops as kops
    from repro.lda.model import LDAConfig
    from repro.lda.trainer import LDATrainer

    cfg = LDAConfig(n_topics=16, tile_size=512)
    tr = LDATrainer(small_corpus, cfg, _from_engine=True)
    state = tr.init_state()
    for _ in range(5):
        state, _ = tr.step(state)
    L = int(np.asarray(state.D).astype(bool).sum(1).max())  # max row nnz
    packed_d = build_sparse_rows(state.D, capacity=L)
    W_hat = esca.compute_w_hat(state.W, cfg.beta)
    key = jax.random.PRNGKey(0)
    t_sp, _ = kops.sample_tokens_sparse_d(
        key, tr.word_ids, tr.doc_ids, state.topics, packed_d, state.D,
        W_hat, alpha=cfg.alpha_, interpret=True)
    # same key -> same u; dense exact reference
    u = jax.random.uniform(key, tr.word_ids.shape, dtype=jnp.float32)
    sw = three_branch.word_stats(W_hat, g=2, alpha=cfg.alpha_)
    t_ref, _ = three_branch.exact_three_branch(
        u, tr.word_ids, tr.doc_ids, sw.k[:, 0], state.D, W_hat,
        alpha=cfg.alpha_, tile_size=512)
    # sparse path orders the CDF by ELL slots, dense by topic id — same
    # per-topic mass, different u->topic maps; compare distributions
    h_sp = np.bincount(np.asarray(t_sp), minlength=16) / len(t_sp)
    h_rf = np.bincount(np.asarray(t_ref), minlength=16) / len(t_ref)
    assert 0.5 * np.abs(h_sp - h_rf).sum() < 0.05, (h_sp, h_rf)
    # and the M-branch (skip) decisions agree exactly: same u, same M
    dec = three_branch.skip_phase(u, tr.word_ids, tr.doc_ids, state.D, sw,
                                  g=2, alpha=cfg.alpha_)
    agree = np.asarray(t_sp)[np.asarray(dec.skip)] == \
        np.asarray(dec.k1)[np.asarray(dec.skip)]
    assert agree.all()
