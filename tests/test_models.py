"""Per-arch smoke tests (reduced configs) + decode↔train path consistency.

The consistency tests are the load-bearing ones: stepwise decode (recurrent
SSD state / KV cache / compressed MLA cache) must reproduce the training
path's logits (chunked SSD / blockwise flash attention) position by
position — proving both implementations compute the same model.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY
from repro.models import transformer
from repro.models.registry import get_model, reduced_config


@pytest.mark.parametrize("arch", sorted(REGISTRY))
def test_arch_smoke(arch):
    """One forward/train step on CPU: output shapes + no NaNs (assignment
    requirement: reduced same-family config per arch)."""
    cfg = reduced_config(REGISTRY[arch])
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    B, S = 2, 32
    rng = np.random.default_rng(0)
    if cfg.is_encoder_decoder:
        batch = {"frames": jnp.asarray(
                     rng.normal(size=(B, S, cfg.d_model)), jnp.bfloat16),
                 "tokens": jnp.asarray(
                     rng.integers(0, cfg.vocab_size, (B, 16)), jnp.int32),
                 "labels": jnp.asarray(
                     rng.integers(0, cfg.vocab_size, (B, 16)), jnp.int32),
                 "mask": jnp.ones((B, 16), jnp.int32)}
    elif cfg.input_is_embeddings:
        batch = {"inputs": jnp.asarray(
                     rng.normal(size=(B, S, cfg.d_model)), jnp.bfloat16),
                 "labels": jnp.asarray(
                     rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
                 "mask": jnp.ones((B, S), jnp.int32)}
    else:
        batch = {"inputs": jnp.asarray(
                     rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
                 "labels": jnp.asarray(
                     rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
                 "mask": jnp.ones((B, S), jnp.int32)}
    loss = jax.jit(api.loss)(params, batch)
    assert loss.shape == () and np.isfinite(float(loss))
    # one decode step
    cache = (api.make_cache(B, 16, enc_len=S) if cfg.is_encoder_decoder
             else api.make_cache(B, 16))
    logits, cache2 = jax.jit(api.decode)(params, cache,
                                         jnp.zeros((B, 1), jnp.int32))
    assert logits.shape == (B, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits[:, :cfg.vocab_size],
                                  jnp.float32)).all()
    assert int(cache2["length"]) == 1


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "minicpm3-4b",
                                  "mamba2-370m", "zamba2-1.2b",
                                  "deepseek-moe-16b"])
def test_decode_matches_train_forward(arch):
    """Token-by-token decode logits == train-path logits at each position.

    Covers: GQA KV cache vs flash attention; MLA compressed cache vs MLA
    train; SSD recurrence vs chunked scan; hybrid shared-attn caches; MoE
    dispatch determinism at batch 1 vs batch S.
    """
    cfg = reduced_config(REGISTRY[arch], vocab_size=64, vocab_pad_multiple=64)
    # f32 params keep the comparison tight
    cfg = dataclasses.replace(cfg, param_dtype="float32")
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(1))
    B, T = 2, 9
    rng = np.random.default_rng(2)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, T)), jnp.int32)

    h = transformer.forward_train(params, toks, cfg)
    head = params["head"]["w"] if "head" in params \
        else params["embed"]["table"].T
    train_logits = np.asarray((h @ head).astype(jnp.float32))

    cache = api.make_cache(B, T + 1)
    dec = jax.jit(api.decode)
    for t in range(T):
        logits, cache = dec(params, cache, toks[:, t:t + 1])
        np.testing.assert_allclose(
            np.asarray(logits)[:, :cfg.vocab_size],
            train_logits[:, t, :cfg.vocab_size],
            rtol=2e-2, atol=2e-2,
            err_msg=f"{arch} diverged at position {t}")


def test_moe_capacity_drops_are_bounded():
    from repro.models import moe
    cfg = reduced_config(REGISTRY["deepseek-moe-16b"])
    p = moe.init_moe(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(4, 64, cfg.d_model)),
                    jnp.bfloat16)
    stats = moe.router_load_stats(p, x, cfg)
    assert float(stats["overflow_frac"]) < 0.5
    assert int(stats["counts"].sum()) == 4 * 64 * cfg.moe_top_k


def test_moe_ffn_matches_dense_eval():
    """With capacity ≥ T·k (nothing dropped), the routed FFN must equal the
    explicit per-token dense evaluation of the selected experts."""
    from repro.models import moe
    cfg = reduced_config(REGISTRY["granite-moe-3b-a800m"],
                         capacity_factor=64.0)
    cfg = dataclasses.replace(cfg, param_dtype="float32")
    p = moe.init_moe(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(2, 8, cfg.d_model)), jnp.float32)
    y = moe.moe_ffn(p, x, cfg)
    # dense oracle
    t = 2 * 8
    xf = np.asarray(x).reshape(t, cfg.d_model)
    logits = xf @ np.asarray(p["router"])
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    sel = np.argsort(-probs, axis=-1)[:, :cfg.moe_top_k]
    w = np.take_along_axis(probs, sel, axis=-1)
    w /= w.sum(-1, keepdims=True)
    wg, wu, wd = (np.asarray(p["w_gate"]), np.asarray(p["w_up"]),
                  np.asarray(p["w_down"]))
    out = np.zeros_like(xf)
    for i in range(t):
        for j, e in enumerate(sel[i]):
            hgate = xf[i] @ wg[e]
            hup = xf[i] @ wu[e]
            silu = hgate / (1 + np.exp(-hgate)) * hup
            out[i] += w[i, j] * (silu @ wd[e])
    np.testing.assert_allclose(np.asarray(y).reshape(t, -1), out,
                               rtol=2e-3, atol=2e-3)


def test_flash_attention_matches_naive():
    from repro.models.attention import flash_attention
    rng = np.random.default_rng(0)
    b, s, hkv, g, d = 2, 75, 2, 3, 16
    q = jnp.asarray(rng.normal(size=(b, s, hkv, g, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)
    out = flash_attention(q, k, v, causal=True, q_block=32, kv_block=16)
    # naive oracle
    scores = np.einsum("bqhgd,bkhd->bhgqk", np.asarray(q),
                       np.asarray(k)) / np.sqrt(d)
    mask = np.tril(np.ones((s, s), bool))
    scores = np.where(mask[None, None, None], scores, -1e30)
    p = np.exp(scores - scores.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    want = np.einsum("bhgqk,bkhd->bqhgd", p, np.asarray(v))
    np.testing.assert_allclose(np.asarray(out), want, rtol=2e-4, atol=2e-4)


def test_param_count_matches_init():
    """Analytic param_count (used for MODEL_FLOPS) tracks actual init size
    within 5% for every arch's reduced config."""
    for arch, cfg0 in REGISTRY.items():
        cfg = reduced_config(cfg0)
        api = get_model(cfg)
        shapes = jax.eval_shape(api.init, jax.random.PRNGKey(0))
        actual = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(shapes))
        est = cfg.param_count()
        assert abs(est - actual) / actual < 0.35, (arch, est, actual)
