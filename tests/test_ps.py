"""The word-sharded parameter server (repro.lda.ps, DESIGN.md SS15).

Four layers, cheapest first:

  1. OwnerLayout — the contiguous word-range partition is EXACT
     (property-tested: disjoint ranges covering [0, V) for any
     (n_words, n_owners, layout), owner_of/owners_touching agree).
  2. The wire protocol — round-commit SSP clock, staleness gating,
     duplicate-push dedup, lost-push resend, owner kill + journal-replay
     revive. Pure numpy, no jax dispatch.
  3. The engine/API surface — DistConfig validation, backend routing.
  4. Forged 8-device legs (slow/chaos markers, subprocess) — the PR's
     acceptance pins: staleness=0 bitwise-equal to the replicated psum
     path for dense AND hybrid, mid-epoch ps_* checkpoints resuming
     bit-identically (including across w_sync strategies and after an
     injected owner kill), chaos drills leaving trajectories unchanged.
"""

import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.lda.ps import (OwnerLayout, ParameterServer, PSClient,
                          PushJournal, StalenessViolation)
from repro.runtime import chaos
from tests._hyp import given, settings, st

# ---------------------------------------------------------------------------
# 1. OwnerLayout: the partition is exact
# ---------------------------------------------------------------------------


def _check_partition(layout: OwnerLayout) -> None:
    starts = layout.starts
    assert starts[0] == 0 and starts[-1] == layout.n_words
    assert all(b >= a for a, b in zip(starts, starts[1:]))
    # ranges are disjoint and cover [0, V): every row has exactly one owner
    covered = np.zeros(layout.n_words, np.int64)
    for o in range(layout.n_owners):
        a, b = layout.range_of(o)
        covered[a:b] += 1
    assert (covered == 1).all()
    for row in range(layout.n_words):
        o = layout.owner_of(row)
        a, b = layout.range_of(o)
        assert a <= row < b


@settings(max_examples=200, deadline=None)
@given(n_words=st.integers(1, 400), n_owners=st.integers(1, 12),
       mass_seed=st.integers(0, 2**31 - 1),
       layout=st.sampled_from(["rows", "mass"]))
def test_owner_partition_exact_property(n_words, n_owners, mass_seed,
                                        layout):
    """For ANY (V, n_owners, layout) the owner ranges partition [0, V)."""
    mass = None
    if layout == "mass":
        mass = np.random.default_rng(mass_seed).zipf(1.8, size=n_words)
    _check_partition(OwnerLayout.build(n_words, n_owners, layout=layout,
                                       row_mass=mass))


def test_owner_partition_exact_seeded():
    """The same invariant without hypothesis (the shim skips the property
    test when hypothesis is absent; this keeps the invariant pinned)."""
    rng = np.random.default_rng(0)
    for n_words, n_owners in [(1, 1), (1, 5), (7, 3), (100, 7), (150, 8),
                              (64, 64), (10, 16)]:
        _check_partition(OwnerLayout.build(n_words, n_owners))
        mass = rng.zipf(1.8, size=n_words)
        _check_partition(OwnerLayout.build(n_words, n_owners,
                                           layout="mass", row_mass=mass))


def test_mass_layout_splits_hot_prefix():
    """Zipf-style mass concentrated in early rows: the mass layout gives
    owner 0 FEWER rows than the uniform split (it holds the hot words)."""
    n_words = 200
    mass = 1.0 / (np.arange(n_words) + 1.0) ** 2
    rows = OwnerLayout.build(n_words, 4, layout="rows")
    massy = OwnerLayout.build(n_words, 4, layout="mass", row_mass=mass)
    assert (massy.starts[1] - massy.starts[0]) \
        < (rows.starts[1] - rows.starts[0])
    _check_partition(massy)


def test_owner_layout_rejects_bad_starts():
    with pytest.raises(ValueError, match="0..n_words"):
        OwnerLayout(n_words=10, starts=(0, 5, 9))
    with pytest.raises(ValueError, match="non-decreasing"):
        OwnerLayout(n_words=10, starts=(0, 7, 5, 10))
    with pytest.raises(ValueError, match="row_mass"):
        OwnerLayout.build(10, 2, layout="mass", row_mass=np.ones(9))


def test_owners_touching_matches_owner_of():
    layout = OwnerLayout.build(100, 7)
    for lo, hi in [(0, 100), (13, 14), (10, 60), (99, 100), (30, 30)]:
        want = sorted({layout.owner_of(r) for r in range(lo, hi)})
        assert layout.owners_touching(lo, hi) == want


# ---------------------------------------------------------------------------
# 2. wire protocol: clock, dedup, journals, recovery (pure numpy)
# ---------------------------------------------------------------------------

V, K = 20, 4


def _server(n_workers=2, n_owners=2, staleness=0, seed=0):
    layout = OwnerLayout.build(V, n_owners)
    srv = ParameterServer(layout, K, n_workers, staleness=staleness)
    W = np.random.default_rng(seed).integers(0, 50, (V, K)).astype(np.int32)
    srv.load_global(W)
    return srv, W


def test_round_commits_only_when_all_workers_finish():
    srv, W = _server()
    a, b = PSClient(srv, 0), PSClient(srv, 1)
    d = np.ones((V, K), np.int32)
    a.push_page(0, V, d)
    a.finish_round()
    # worker 1 still in round 0: nothing committed, pulls see the old rows
    assert srv.committed == 0
    assert np.array_equal(b.pull_page(0, V), W)
    b.push_page(0, V, 2 * d)
    b.finish_round()
    assert srv.committed == 1
    assert np.array_equal(a.pull_page(0, V), W + 3)
    assert np.array_equal(srv.gather_global(), W + 3)


def test_staleness_gate():
    srv, _ = _server(n_workers=2, staleness=1)
    fast, slow = PSClient(srv, 0), PSClient(srv, 1)
    # fast worker finishes rounds 0 and 1 alone; committed stays 0
    for _ in range(2):
        fast.push_page(0, V, np.ones((V, K), np.int32))
        fast.finish_round()
    # clock 1 is within staleness=1 of committed=0; clock 2 is not
    assert srv.can_pull(1) and not srv.can_pull(2)
    assert not fast.can_advance()
    with pytest.raises(StalenessViolation):
        fast.pull_page(0, V)
    with pytest.raises(StalenessViolation):
        srv.pull_colsum(clock=2)
    # the slowest worker is always admissible
    assert slow.can_advance()


def test_staleness_zero_pulls_see_exactly_committed():
    srv, W = _server(staleness=0)
    c0, c1 = PSClient(srv, 0), PSClient(srv, 1)
    c0.push_page(0, 10, np.full((10, K), 3, np.int32))
    # queued, not applied: a same-round pull still sees committed rows
    assert np.array_equal(c0.pull_page(0, 10), W[:10])
    c0.finish_round()
    c1.finish_round()
    assert np.array_equal(c0.pull_page(0, 10), W[:10] + 3)


def test_duplicate_push_acks_without_reapplying():
    srv, W = _server(n_workers=1)
    blk = np.ones((5, K), np.int32)
    assert srv.push_page(0, 0, 7, 0, 5, blk)
    assert srv.push_page(0, 0, 7, 0, 5, blk)    # replay of the same seq
    srv.finish_round(0, 0)
    assert np.array_equal(srv.gather_global()[:5], W[:5] + 1)


def test_colsum_is_exact_int():
    srv, W = _server(n_owners=3)
    assert np.array_equal(srv.pull_colsum(clock=0),
                          W.sum(axis=0).astype(np.int32))


def test_journal_accumulates_per_owner_and_trims():
    layout = OwnerLayout.build(V, 2)
    j = PushJournal(0, layout, K)
    # two pages straddling the owner boundary (V//2) in one round
    j.record(0, 5, 15, np.ones((10, K), np.int32))
    j.record(0, 8, 18, np.ones((10, K), np.int32))
    b0, b1 = j.blocks_for(0, 0), j.blocks_for(0, 1)
    assert b0.shape == (10, K) and b1.shape == (10, K)
    assert int(b0.sum() + b1.sum()) == 2 * 10 * K
    assert j.nbytes() > 0
    j.trim(0)
    assert j.blocks_for(0, 0) is None and j.nbytes() == 0


def test_note_checkpoint_requires_committed_clock():
    srv, _ = _server()
    with pytest.raises(ValueError, match="committed"):
        srv.note_checkpoint(3, journals=())


@pytest.mark.chaos
def test_lost_push_resent_from_journal():
    srv, W = _server(n_workers=1)
    c = PSClient(srv, 0)
    with chaos.active(chaos.FaultPlan(ps_lose_pushes=((0, 0),))):
        c.push_page(0, V, np.ones((V, K), np.int32))   # nack -> resend
        c.finish_round()
    assert np.array_equal(srv.gather_global(), W + 1)
    # journal recorded the push exactly once despite the wire retry
    assert c.journal.next_seq == 1


@pytest.mark.chaos
def test_owner_kill_revive_replays_journals():
    srv, W = _server(n_workers=2, n_owners=2)
    a, b = PSClient(srv, 0), PSClient(srv, 1)
    # round 0 commits normally
    for c in (a, b):
        c.push_page(0, V, np.ones((V, K), np.int32))
        c.finish_round()
    # round 1: worker 0's push is pending (uncommitted) when owner 1 dies
    a.push_page(0, V, np.full((V, K), 5, np.int32))
    srv.kill_owner(1)
    with pytest.raises(RuntimeError, match="dead"):
        b.pull_page(0, V)
    with pytest.raises(RuntimeError, match="dead"):
        b.pull_colsum()
    srv.revive_owner(1, journals=[a.journal, b.journal])
    # committed rounds replayed exactly; pending round re-queued
    assert np.array_equal(srv.gather_global(), W + 2)
    a.finish_round()
    b.finish_round()
    assert np.array_equal(srv.gather_global(), W + 7)


@pytest.mark.chaos
def test_revive_requires_all_journals_and_live_owner_check():
    srv, _ = _server(n_workers=2)
    with pytest.raises(ValueError, match="not dead"):
        srv.revive_owner(0, journals=[None, None])
    srv.kill_owner(0)
    with pytest.raises(ValueError, match="journals"):
        srv.revive_owner(0, journals=[None])


def test_owner_bytes_are_a_fraction_of_global():
    layout = OwnerLayout.build(4096, 8)
    srv = ParameterServer(layout, 64, 4)
    global_bytes = 4096 * 64 * 4
    assert srv.max_owner_nbytes() <= global_bytes / 8 + 64 * 4


# ---------------------------------------------------------------------------
# 3. DistConfig validation + backend routing
# ---------------------------------------------------------------------------


def test_dist_config_validation():
    from repro.lda.model import DistConfig
    with pytest.raises(ValueError, match="w_sync"):
        DistConfig(w_sync="gossip")
    with pytest.raises(ValueError, match="staleness"):
        DistConfig(staleness=-1)
    with pytest.raises(ValueError, match="w_sync='ps'"):
        DistConfig(staleness=2)                 # staleness needs ps
    with pytest.raises(ValueError, match="w_sync='ps'"):
        DistConfig(n_owners=4)                  # owner knobs need ps
    with pytest.raises(ValueError, match="owner_layout"):
        DistConfig(w_sync="ps", owner_layout="hash")
    with pytest.raises(ValueError, match="mesh_shape"):
        DistConfig(mesh_shape=(("data",),))
    DistConfig(w_sync="ps", staleness=3, n_owners=2, owner_layout="mass")


def test_ps_trainer_rejects_incompatible_configs(small_corpus):
    from repro.lda.distributed import PSDistTrainer
    from repro.lda.model import DistConfig, LDAConfig
    from repro.runtime.compat import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    kw = dict(n_topics=8, tile_size=256)
    with pytest.raises(ValueError, match="balance"):
        PSDistTrainer(small_corpus, LDAConfig(
            **kw, dist=DistConfig(w_sync="ps", balance="tiles")),
            mesh, _from_engine=True)
    with pytest.raises(ValueError, match="warp"):
        PSDistTrainer(small_corpus, LDAConfig(
            **kw, sampler="warp", dist=DistConfig(w_sync="ps")),
            mesh, _from_engine=True)


def test_engine_single_backend_rejects_ps(small_corpus):
    from repro.lda.api import LDAEngine
    from repro.lda.model import DistConfig, LDAConfig
    with pytest.raises(ValueError, match="parameter server"):
        LDAEngine(small_corpus, LDAConfig(
            n_topics=8, dist=DistConfig(w_sync="ps")), backend="single")


# ---------------------------------------------------------------------------
# 4. forged 8-device legs: the acceptance pins
# ---------------------------------------------------------------------------

_PRELUDE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import numpy as np, jax
from repro.lda.corpus import synthetic_lda_corpus, relabel_by_frequency
from repro.lda.model import LDAConfig, DistConfig
from repro.lda.distributed import DistLDATrainer, PSDistTrainer
from repro.runtime.compat import make_mesh
from repro.runtime import chaos

K = 16
corpus = synthetic_lda_corpus(3, n_docs=40, n_words=150, n_topics=K,
                              mean_doc_len=75)
corpus, _ = relabel_by_frequency(corpus)
mesh = make_mesh((4, 1), ("data", "model"))

def mk(staleness=0, n_owners=None, fmt="dense"):
    cfg = LDAConfig(n_topics=K, seed=11, format=fmt,
                    dist=DistConfig(w_sync="ps", staleness=staleness,
                                    n_owners=n_owners))
    return PSDistTrainer(corpus, cfg, mesh, pad_multiple=64,
                         _from_engine=True)

def mk_rep(fmt="dense"):
    return DistLDATrainer(corpus, LDAConfig(n_topics=K, seed=11,
                                            format=fmt), mesh,
                          pad_multiple=64, _from_engine=True)
"""


def _run_forged(body: str, timeout: int = 900) -> None:
    proc = subprocess.run(
        [sys.executable, "-c", _PRELUDE + textwrap.dedent(body)],
        capture_output=True, text=True, timeout=timeout, cwd=".")
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "ALL OK" in proc.stdout, proc.stdout[-2000:]


@pytest.mark.slow
def test_ps_staleness0_bitwise_vs_replicated_forged():
    """staleness=0 PS == replicated psum, bitwise, dense AND hybrid —
    and each owner shard holds a strict fraction of the global W bytes."""
    _run_forged("""
    for fmt in ("dense", "hybrid"):
        rep, pst = mk_rep(fmt), mk(fmt=fmt)
        s_r, _ = rep.run_fused(rep.init_state(), 4)
        s_p, _ = pst.run_fused(pst.init_state(), 4)
        D_r, W_r = rep.gather_global(s_r)
        D_p, W_p = pst.gather_global(s_p)
        assert np.array_equal(np.asarray(W_r), W_p), fmt
        assert np.array_equal(np.asarray(D_r), D_p), fmt
        p_r, p_p = rep.host_payload(s_r), pst.host_payload(s_p)
        assert np.array_equal(p_r["topics_global"], p_p["topics_global"])
        assert p_r["iteration"] == p_p["iteration"] == 4
        pst.selfcheck(s_p)
        owner = s_p.server.max_owner_nbytes()
        glob = np.asarray(W_p).nbytes
        assert owner <= 0.35 * glob, (fmt, owner, glob)
    print("ALL OK")
    """)


@pytest.mark.slow
def test_ps_mid_epoch_payload_and_interchange_forged():
    """Mid-round ps_* checkpoints resume bit-identically; payloads
    interchange across w_sync strategies (PS mid-epoch -> replicated
    restores at the cut and redoes the round to the same trajectory)."""
    _run_forged("""
    t0 = mk()
    s0, _ = t0.run_fused(t0.init_state(), 4)
    refD, refW = t0.gather_global(s0)

    t1 = mk()
    s1, _ = t1.run_fused(t1.init_state(), 2)
    s1 = t1.run_shards(s1, 2)            # 2 sub-shards into round 2
    assert s1.cursors.any()
    pay = t1.host_payload(s1)
    assert "ps_cursors" in pay and pay["iteration"] == 2
    t1b = mk()
    s1b = t1b.state_from_payload(pay)
    s1b, _ = t1b.run_fused(s1b, 2)
    s1, _ = t1.run_fused(s1, 2)
    D, W = t1.gather_global(s1)
    Db, Wb = t1b.gather_global(s1b)
    assert np.array_equal(W, Wb) and np.array_equal(D, Db)
    assert np.array_equal(W, refW) and np.array_equal(D, refD)

    # PS mid-epoch payload -> replicated backend: restores at the cut,
    # redoing the round reproduces the identical trajectory
    rep = mk_rep()
    sr = rep.state_from_payload(pay)
    sr, _ = rep.run_fused(sr, 2)
    Dr, Wr = rep.gather_global(sr)
    assert np.array_equal(np.asarray(Wr), refW)
    assert np.array_equal(np.asarray(Dr), refD)
    # replicated boundary payload -> PS backend
    pr = rep.host_payload(sr)
    t5 = mk()
    s5 = t5.state_from_payload(pr)
    assert s5.iteration == 4
    D5, W5 = t5.gather_global(s5)
    assert np.array_equal(W5, refW) and np.array_equal(D5, refD)
    print("ALL OK")
    """)


@pytest.mark.slow
@pytest.mark.chaos
def test_ps_chaos_drills_forged():
    """Owner kill (snapshot + journal replay), lost pushes (journal
    resend), and a slow-worker clock bias under staleness=2 all leave the
    final counts bitwise-equal to the undisturbed run; a mid-epoch ps_*
    checkpoint restores bit-identically after an injected owner kill."""
    _run_forged("""
    t0 = mk()
    s0, _ = t0.run_fused(t0.init_state(), 4)
    refD, refW = t0.gather_global(s0)

    # owner kill after a checkpoint: revive = snapshot + journal replay
    t3 = mk(n_owners=3)
    s3, _ = t3.run_fused(t3.init_state(), 1)
    _ = t3.host_payload(s3)              # checkpoint: snapshot + trim
    with chaos.active(chaos.FaultPlan(ps_kill_owners=((1, 3),))):
        s3, _ = t3.run_fused(s3, 3)
    D3, W3 = t3.gather_global(s3)
    assert np.array_equal(W3, refW) and np.array_equal(D3, refD)

    # lost pushes: client resends from its journal until acked
    t4 = mk()
    with chaos.active(chaos.FaultPlan(ps_lose_pushes=((2, 1), (0, 3)))):
        s4, _ = t4.run_fused(t4.init_state(), 4)
    D4, W4 = t4.gather_global(s4)
    assert np.array_equal(W4, refW) and np.array_equal(D4, refD)

    # staleness=2 + slow-worker bias: genuinely stale pulls, SSP bound
    # holds, run converges (trajectory may legitimately differ)
    t2 = mk(staleness=2)
    with chaos.active(chaos.FaultPlan(ps_slow_workers={0: 2})):
        s2, _ = t2.run_fused(t2.init_state(), 4)
    assert int(s2.clocks.min()) == 4 and int(s2.clocks.max()) == 4
    t2.selfcheck(s2)

    # mid-epoch checkpoint + owner kill -> restore resumes bit-identically
    t6 = mk(n_owners=3)
    s6, _ = t6.run_fused(t6.init_state(), 2)
    s6 = t6.run_shards(s6, 2)
    pay = t6.host_payload(s6)            # the durable mid-round cut
    with chaos.active(chaos.FaultPlan(ps_kill_owners=((2, 2),))):
        s6, _ = t6.run_fused(s6, 2)      # kill + revive in-run
    t6b = mk(n_owners=3)
    s6b = t6b.state_from_payload(pay)    # restore from the pre-kill cut
    s6b, _ = t6b.run_fused(s6b, 2)
    D6, W6 = t6.gather_global(s6)
    D6b, W6b = t6b.gather_global(s6b)
    assert np.array_equal(W6, W6b) and np.array_equal(D6, D6b)
    assert np.array_equal(W6, refW) and np.array_equal(D6, refD)
    print("ALL OK")
    """)


@pytest.mark.slow
def test_ps_engine_supervised_shardwise_forged():
    """The engine front door: DistConfig(w_sync='ps') routes to the PS
    trainer, shard-wise supervised fit cuts mid-round ps_* checkpoints,
    and the result matches the plain fused engine run bitwise."""
    code = """
    import os, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, "src")
    import numpy as np
    from repro.lda.api import LDAEngine, SupervisePolicy
    from repro.lda.corpus import synthetic_lda_corpus, relabel_by_frequency
    from repro.lda.model import LDAConfig, DistConfig

    corpus = synthetic_lda_corpus(3, n_docs=40, n_words=150, n_topics=8,
                                  mean_doc_len=75)
    corpus, _ = relabel_by_frequency(corpus)
    kw = dict(n_topics=16, tile_size=256, seed=11, eval_every=2,
              dist=DistConfig(w_sync="ps"))
    eng = LDAEngine(corpus, LDAConfig(**kw), pad_multiple=64)
    assert eng.backend_name == "distributed" and eng._backend.is_ps
    eng.fit(4)
    W_ref = eng.export().W
    with tempfile.TemporaryDirectory() as d:
        eng2 = LDAEngine(corpus, LDAConfig(**kw), pad_multiple=64,
                         checkpoint_dir=d)
        eng2.fit(4, supervise=SupervisePolicy(checkpoint_shards=1))
        assert np.array_equal(eng2.export().W, W_ref)
    print("ALL OK")
    """
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=900, cwd=".")
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "ALL OK" in proc.stdout, proc.stdout[-2000:]
