"""Roofline tooling tests: HLO shape/collective parsing + the analytic cost
model calibrated against fully-unrolled HLO (where HloCostAnalysis is exact)."""

import subprocess
import sys
import textwrap

import pytest

from repro.roofline.analysis import (collective_bytes, parse_shape_bytes)


def test_parse_shape_bytes():
    assert parse_shape_bytes("f32[16,128]") == 16 * 128 * 4
    assert parse_shape_bytes("bf16[8]{0}") == 16
    assert parse_shape_bytes("pred[]") == 1
    assert parse_shape_bytes("s32[2,2]{1,0:T(2,2)}") == 16
    # async pair: take the destination buffer (last element)
    assert parse_shape_bytes("(f32[4]{0}, f32[16]{0})") == 64


def test_collective_bytes_ring_model():
    hlo = """
  %ar = f32[1024]{0} all-reduce(f32[1024]{0} %x), replica_groups={{0,1,2,3}}
  %ag = bf16[64,16]{1,0} all-gather(bf16[16,16]{1,0} %y), replica_groups={{0,1,2,3}}
  %rs = f32[256]{0} reduce-scatter(f32[1024]{0} %z), replica_groups={{0,1,2,3}}
  %done = f32[8]{0} all-reduce-done(f32[8]{0} %h)
    """
    out = collective_bytes(hlo, 4)
    assert out["all-reduce"] == pytest.approx(2 * 4096 * 3 / 4)
    assert out["all-gather"] == pytest.approx(64 * 16 * 2 * 3 / 4)
    assert out["reduce-scatter"] == pytest.approx(256 * 4 * 3)
    assert out["count"] == 3          # -done not double counted


def test_collective_bytes_iota_groups():
    hlo = "%ar = f32[100]{0} all-reduce(f32[100]{0} %x), replica_groups=[2,8]<=[16]"
    out = collective_bytes(hlo, 16)
    assert out["all-reduce"] == pytest.approx(2 * 400 * 7 / 8)


@pytest.mark.slow
def test_analytic_model_calibration():
    """Analytic FLOPs within 15% of fully-unrolled HLO for dense + ssm.

    (Unrolled ⇒ no while loops ⇒ HloCostAnalysis counts everything; this is
    the ground truth the rolled dry-run's analytic numbers stand on.)
    """
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
        import sys; sys.path.insert(0, "src")
        import dataclasses, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import REGISTRY, SHAPES
        from repro.models.registry import get_model, input_specs
        from repro.roofline.analysis import roofline_terms
        from repro.roofline.flops_model import analytic_cell
        from repro.train.train_step import (make_train_step,
            train_state_specs, batch_shardings)
        from repro.train.optimizer import init_opt_state
        mesh = jax.make_mesh((4, 4), ("data", "model"))
        for arch in ("qwen1.5-0.5b", "mamba2-370m"):
            cfg = dataclasses.replace(REGISTRY[arch], scan_unroll=True,
                                      n_layers=4)
            api = get_model(cfg)
            shape = dataclasses.replace(SHAPES["train_4k"], seq_len=512,
                                        global_batch=8)
            pshape = jax.eval_shape(api.init, jax.random.PRNGKey(0))
            step, _ = make_train_step(api, mesh, n_micro=1)
            st_sh = train_state_specs(mesh, pshape)
            o_sh = jax.eval_shape(init_opt_state, pshape)
            st = {"params": pshape, "opt": o_sh,
                  "step": jax.ShapeDtypeStruct((), jnp.int32)}
            bspec = input_specs(cfg, 512, 8, "train")
            rep = NamedSharding(mesh, P())
            low = jax.jit(step, in_shardings=(st_sh,
                          batch_shardings(mesh, bspec)),
                          out_shardings=(st_sh, {"grad_norm": rep,
                                                 "lr": rep, "loss": rep})
                          ).lower(st, bspec)
            rf = roofline_terms(low.compile(), 16)
            cost = analytic_cell(cfg, shape, {"data": 4, "model": 4},
                                 n_micro=1)
            ratio = rf["hlo_flops"] / cost.flops
            assert 0.85 < ratio < 1.2, (arch, ratio)
            print(arch, round(ratio, 3))
        print("OK")
    """)
    proc = subprocess.run([sys.executable, "-c", code],
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0 and "OK" in proc.stdout, proc.stderr[-3000:]


@pytest.mark.slow
def test_dryrun_cell_end_to_end():
    """The dry-run machinery itself: one real cell on the production 16×16
    mesh (whisper decode — the fastest compile), lowered + compiled +
    analyzed in a subprocess exactly as the sweep runs it."""
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "whisper-base", "--shape", "decode_32k", "--mesh", "multi"],
        capture_output=True, text=True, timeout=900,
        env={**__import__("os").environ, "PYTHONPATH": "src"})
    assert proc.returncode == 0, proc.stderr[-3000:]
    import json
    d = json.loads(proc.stdout)
    assert d["status"] == "ok"
    assert d["mesh_shape"] == {"pod": 2, "data": 16, "model": 16}
    assert d["roofline"]["dominant"] in ("compute_s", "memory_s",
                                         "collective_s")
    assert d["roofline_hlo_raw"]["collectives"]["count"] > 0
