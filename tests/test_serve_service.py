"""Serving-tier tests (repro/serve): micro-batching service, replicas,
hot-word cache, bounded-staleness refresh, chaos fault drills.

The load-bearing properties:
  1. The service answers a concurrent single-doc stream: every accepted
     future resolves to a finite (K,) θ row, metrics account for it.
  2. The hot-word cache is BITWISE-equal to full tables: every per-word
     quantity (ŵ, the three-branch stats, the alias tables) is row-local,
     so slice-then-build == build-then-slice, and a cached fold-in under
     the same key reproduces the uncached one bit for bit.
  3. Bounded-staleness refresh at an epoch boundary is bitwise-equal to
     freezing a boundary checkpoint — the acceptance pin: a service that
     followed the live trainer's publishes answers exactly like a service
     built fresh from the final export, θ and LLPT.
  4. Chaos drills (-m chaos): a replica killed holding a batch loses no
     accepted request (re-queued, answered by the survivor); a straggler
     replica delays only its own batch (work stealing re-routes the
     rest); refresh under traffic never serves a torn W.
  5. Backpressure is real: with the dispatch backlog bounded and the
     pending queue full, submit() sheds load with ServiceOverloaded.
"""

import threading
import time

import numpy as np
import pytest

import jax

from repro.lda.api import LDAEngine
from repro.lda.corpus import synthetic_lda_corpus
from repro.lda.model import LDAConfig, head_rows_for_coverage
from repro.runtime import chaos
from repro.serve import (HotWordCache, LDAService, LatencyHistogram,
                         Replica, ReplicaDead, ServeConfig, ServeMetrics,
                         ServiceOverloaded, ServingSnapshot, attach)
from repro.serve.replicas import pack_docs

jax.config.update("jax_platform_name", "cpu")

V, K = 40, 8


@pytest.fixture(scope="module")
def engine():
    corpus = synthetic_lda_corpus(0, n_docs=50, n_words=V, n_topics=4,
                                  mean_doc_len=14)
    eng = LDAEngine(corpus,
                    LDAConfig(n_topics=K, tile_size=256, eval_every=50,
                              corpus_residency="streamed", stream_shards=4),
                    backend="single")
    eng.fit(3)
    return eng


@pytest.fixture(scope="module")
def model(engine):
    return engine.export()


@pytest.fixture(scope="module")
def qdocs():
    rng = np.random.default_rng(7)
    return [rng.integers(0, V, size=rng.integers(4, 20)).tolist()
            for _ in range(48)]


def small_cfg(**kw):
    base = dict(max_batch=16, buckets=(4, 8, 16), max_delay_ms=1.0,
                n_replicas=2, n_sweeps=2, token_floor=64, seed=0)
    base.update(kw)
    return ServeConfig(**base)


# ---------------------------------------------------------------------------
# config validation & sizing helpers
# ---------------------------------------------------------------------------

def test_serve_config_validation():
    with pytest.raises(ValueError, match="powers of two"):
        ServeConfig(buckets=(3, 8))
    with pytest.raises(ValueError, match="ascending"):
        ServeConfig(buckets=(16, 8))
    with pytest.raises(ValueError, match="largest bucket"):
        ServeConfig(max_batch=64, buckets=(8, 16))
    with pytest.raises(ValueError, match="hot_coverage"):
        ServeConfig(hot_coverage=1.5)
    with pytest.raises(ValueError, match="not both"):
        ServeConfig(hot_words=8, hot_coverage=0.8)
    with pytest.raises(ValueError, match="n_sweeps"):
        ServeConfig(n_sweeps=0)


def test_head_rows_for_coverage():
    assert head_rows_for_coverage([5, 3, 1, 1], 0.8) == 2
    assert head_rows_for_coverage([5, 3, 1, 1], 1.0) == 4
    assert head_rows_for_coverage([0, 0], 0.9) == 1   # nothing to cover
    with pytest.raises(ValueError, match="coverage"):
        head_rows_for_coverage([1, 1], 0.0)


def test_pack_docs_validation_and_shape(model):
    with pytest.raises(ValueError, match="word id"):
        pack_docs([[V + 3]], n_words=V, word_map=model.word_map,
                  doc_buckets=(4, 8), token_floor=16)
    packed = pack_docs([[0, 1, 2], [3, 4]], n_words=V,
                       word_map=model.word_map, doc_buckets=(4, 8),
                       token_floor=16)
    assert packed.n_docs == 4 and packed.n_real_docs == 2
    assert packed.word_ids.shape[0] == 16          # pow2 token pad
    assert int(packed.mask.sum()) == 5             # real tokens only


# ---------------------------------------------------------------------------
# 1. the service answers a concurrent stream
# ---------------------------------------------------------------------------

def test_service_answers_stream(model, qdocs):
    with LDAService(model, small_cfg(hot_coverage=0.8)) as svc:
        assert 1 <= svc.hot_words <= V
        futs = [svc.submit(d) for d in qdocs]
        single = svc.infer(qdocs[0], timeout=60)
        thetas = [f.result(timeout=60) for f in futs]
        for th in thetas + [single]:
            assert th.shape == (K,)
            assert np.all(np.isfinite(th))
            assert abs(float(th.sum()) - 1.0) < 1e-4
        st = svc.stats()
        assert st["completed"] == len(qdocs) + 1
        assert st["failed"] == 0 and st["rejected"] == 0
        assert st["batches"] >= 1 and 0 < st["batch_fill"] <= 1
        assert 0 < st["cache_hit_rate"] <= 1
        assert st["latency"]["n"] == len(qdocs) + 1
        assert st["latency"]["p50_ms"] <= st["latency"]["p99_ms"]
        assert st["alive_replicas"] == 2


def test_service_rejects_after_close(model, qdocs):
    svc = LDAService(model, small_cfg())
    svc.close()
    from repro.serve import ServiceClosed
    with pytest.raises(ServiceClosed):
        svc.submit(qdocs[0])


def test_transform_deterministic_under_pinned_key(model, qdocs):
    key = jax.random.PRNGKey(11)
    with LDAService(model, small_cfg()) as svc:
        a = svc.transform(qdocs[:6], key=key, timeout=60)
        b = svc.transform(qdocs[:6], key=key, timeout=60)
    with LDAService(model, small_cfg()) as svc2:
        c = svc2.transform(qdocs[:6], key=key, timeout=60)
    assert np.array_equal(a, b)       # same service, same key
    assert np.array_equal(a, c)       # independent service, same key


# ---------------------------------------------------------------------------
# 2. hot-word cache: bitwise vs full tables, hit accounting
# ---------------------------------------------------------------------------

def test_cache_bitwise_equal_to_full_tables(model, qdocs):
    packed = pack_docs(qdocs[:8], n_words=V, word_map=model.word_map,
                       doc_buckets=(8,), token_floor=64)
    key = jax.random.PRNGKey(3)
    full = Replica(0, model, device=None, hot_words=V, warm_start=True)
    hot = Replica(1, model, device=None, hot_words=6, warm_start=True)
    th_full, ll_full, acc_full = full.infer_packed(packed, key, n_sweeps=2)
    th_hot, ll_hot, acc_hot = hot.infer_packed(packed, key, n_sweeps=2)
    assert np.array_equal(th_full, th_hot)
    assert ll_full == ll_hot
    assert acc_full["cache_misses"] == 0          # full pin: all hits
    assert acc_hot["cache_misses"] > 0            # tail actually gathered
    assert 0 < hot.cache.hit_rate < 1
    assert full.cache.is_full and not hot.cache.is_full


def test_cache_refresh_is_tear_free_pointer_swap(model):
    cache = HotWordCache(model, hot_words=6)
    state0 = cache._state
    W2 = np.asarray(model.W) + np.eye(V, K, dtype=np.int32)
    cache.refresh(W2)
    assert cache._state is not state0             # swapped, not mutated
    ids = np.arange(10, dtype=np.int64)
    asm = cache.assemble(ids)
    assert asm.local_ids.shape == ids.shape


def test_dead_replica_raises(model, qdocs):
    rep = Replica(0, model, device=None, hot_words=V)
    rep.kill()
    packed = pack_docs(qdocs[:2], n_words=V, word_map=model.word_map,
                       doc_buckets=(4,), token_floor=16)
    with pytest.raises(ReplicaDead):
        rep.infer_packed(packed, jax.random.PRNGKey(0), n_sweeps=1)


# ---------------------------------------------------------------------------
# 3. bounded-staleness refresh: the bitwise acceptance pin
# ---------------------------------------------------------------------------

def test_refresh_boundary_bitwise_equals_frozen_checkpoint(tmp_path, qdocs):
    """A service that followed the live trainer's publish stream answers
    — after the epoch-boundary swap — EXACTLY like a service frozen from
    the boundary checkpoint: θ bitwise, LLPT bitwise."""
    corpus = synthetic_lda_corpus(1, n_docs=40, n_words=V, n_topics=4,
                                  mean_doc_len=12)
    from repro.lda.api import SupervisePolicy
    eng = LDAEngine(corpus,
                    LDAConfig(n_topics=K, tile_size=256, eval_every=50,
                              corpus_residency="streamed",
                              stream_shards=4),
                    backend="single", checkpoint_dir=str(tmp_path))
    eng.fit(1)
    svc = LDAService(eng.export(), small_cfg(n_replicas=1))
    snaps = []
    unsub = attach(eng, svc, on_snapshot=snaps.append)
    # shard-wise supervision publishes MID-epoch views and the boundary
    eng.fit(2, supervise=SupervisePolicy(checkpoint_shards=2))
    unsub()
    assert any(s.cursor > 0 for s in snaps), "no mid-epoch publish"
    assert any(s.cursor == 0 for s in snaps), "no boundary publish"
    assert [s.seq for s in snaps] == sorted(s.seq for s in snaps)
    mid = [s for s in snaps if s.cursor > 0][0]
    assert 0 < mid.staleness_steps < 1
    last = snaps[-1]
    assert last.cursor == 0                       # ends on a boundary

    # boundary snapshot == boundary checkpoint == engine export
    assert np.array_equal(last.W, eng.export().W)

    key = jax.random.PRNGKey(23)
    th_refreshed = svc.transform(qdocs[:4], key=key, timeout=60)
    with LDAService(last.freeze(), small_cfg(n_replicas=1)) as ref:
        th_frozen = ref.transform(qdocs[:4], key=key, timeout=60)
    assert np.array_equal(th_refreshed, th_frozen)

    # replica-level: refresh-swap vs fresh-freeze, θ AND llpt bitwise
    packed = pack_docs(qdocs[:4], n_words=V, word_map=eng.word_map,
                       doc_buckets=(4,), token_floor=64)
    swapped = Replica(0, eng.export(), device=None, hot_words=6)
    swapped.refresh(np.asarray(last.W))
    fresh = Replica(1, last.freeze(), device=None, hot_words=6)
    th_a, ll_a, _ = swapped.infer_packed(packed, key, n_sweeps=2)
    th_b, ll_b, _ = fresh.infer_packed(packed, key, n_sweeps=2)
    assert np.array_equal(th_a, th_b) and ll_a == ll_b
    svc.close()


def test_refresh_rejects_incompatible_and_stale(model, engine):
    with LDAService(model, small_cfg()) as svc:
        good = ServingSnapshot(W=np.asarray(model.W), alpha=model.alpha,
                               beta=model.beta, g=model.g, iteration=1,
                               seq=1, word_map=model.word_map)
        assert svc.refresh(good) is True
        assert svc.refresh(good) is False         # same seq: stale, no-op
        wrong_shape = ServingSnapshot(W=np.zeros((V + 1, K), np.int32),
                                      alpha=model.alpha, beta=model.beta,
                                      g=model.g, iteration=1, seq=2)
        with pytest.raises(ValueError, match="shape"):
            svc.refresh(wrong_shape)
        wrong_alpha = ServingSnapshot(W=np.asarray(model.W),
                                      alpha=model.alpha + 1.0,
                                      beta=model.beta, g=model.g,
                                      iteration=1, seq=3)
        with pytest.raises(ValueError, match="alpha"):
            svc.refresh(wrong_alpha)
        assert svc.stats()["refreshes"] == 1


def test_engine_publish_subscribe_surface(engine):
    seen = []
    unsub = engine.subscribe(seen.append)
    snap = engine.publish_serving()
    assert seen and seen[-1] is snap
    assert snap.cursor == 0 and snap.n_shards >= 1
    assert np.array_equal(snap.W, engine.export().W)
    n = len(seen)
    unsub()
    engine.publish_serving()
    assert len(seen) == n                          # unsubscribed


def test_from_engine_snapshot(engine):
    snap = ServingSnapshot.from_engine(engine, seq=5)
    assert snap.seq == 5
    assert np.array_equal(snap.W, engine.export().W)
    m = snap.freeze()
    assert m.n_words == V and m.n_topics == K


# ---------------------------------------------------------------------------
# 4. chaos drills
# ---------------------------------------------------------------------------

@pytest.mark.chaos
def test_chaos_replica_kill_mid_request_completes_all(model, qdocs):
    with LDAService(model, small_cfg(n_replicas=2)) as svc:
        svc.infer(qdocs[0], timeout=60)            # warm both paths
        with chaos.active(chaos.FaultPlan(kill_replicas=(0,))):
            futs = [svc.submit(d) for d in qdocs]
            thetas = [f.result(timeout=60) for f in futs]
        assert all(t.shape == (K,) for t in thetas)
        st = svc.stats()
        assert st["alive_replicas"] == 1           # the kill landed
        assert st["requeued_batches"] >= 1         # batch was re-queued
        assert st["failed"] == 0                   # survivor answered all


@pytest.mark.chaos
def test_chaos_slow_replica_delays_only_its_own_batch(model, qdocs):
    with LDAService(model, small_cfg(n_replicas=2)) as svc:
        groups = [qdocs[i * 4:(i + 1) * 4] for i in range(6)]
        for g in groups:            # warm every exact batch signature
            for f in svc.submit_batch(g):
                f.result(timeout=60)
        done: dict[int, float] = {}
        lock = threading.Lock()
        with chaos.active(chaos.FaultPlan(slow_replicas={0: 0.8})):
            t0 = time.perf_counter()

            def arm(i, futs):
                left = [len(futs)]

                def cb(_):
                    with lock:
                        left[0] -= 1
                        if left[0] == 0:
                            done[i] = time.perf_counter() - t0
                for f in futs:
                    f.add_done_callback(cb)

            batches = [svc.submit_batch(g) for g in groups]
            for i, futs in enumerate(batches):
                arm(i, futs)
            for futs in batches:
                for f in futs:
                    f.result(timeout=60)
        # exactly one batch rode the sleeping replica; work stealing
        # drained the rest on the other one well inside the sleep
        slow = [t for t in done.values() if t >= 0.8]
        fast = [t for t in done.values() if t < 0.5]
        assert len(slow) == 1
        assert len(fast) == len(done) - 1
        assert svc.stats()["failed"] == 0


@pytest.mark.chaos
def test_chaos_refresh_during_traffic_never_tears(model, engine, qdocs):
    W0 = np.asarray(model.W, np.int32)
    W1 = W0 + np.ones_like(W0)                     # a visibly different W
    with LDAService(model, small_cfg(n_replicas=2)) as svc:
        stop = threading.Event()
        errs: list[Exception] = []

        def refresher():
            seq = 1
            while not stop.is_set():
                Wv = W0 if seq % 2 == 0 else W1
                try:
                    svc.refresh(ServingSnapshot(
                        W=Wv, alpha=model.alpha, beta=model.beta,
                        g=model.g, iteration=0, seq=seq))
                except Exception as e:             # never expected
                    errs.append(e)
                    return
                seq += 1

        th = threading.Thread(target=refresher)
        th.start()
        try:
            for _ in range(10):
                futs = [svc.submit(d) for d in qdocs[:16]]
                for f in futs:
                    t = f.result(timeout=60)
                    assert np.all(np.isfinite(t))
        finally:
            stop.set()
            th.join()
        assert not errs
        st = svc.stats()
        assert st["failed"] == 0
        assert st["refreshes"] >= 2

        # settle on W1 and pin: the swapped service must equal a fresh
        # freeze of W1 — if any request had seen a torn half-swapped
        # table set the pointer-swap discipline would be broken
        svc.refresh(ServingSnapshot(W=W1, alpha=model.alpha,
                                    beta=model.beta, g=model.g,
                                    iteration=0, seq=10 ** 6))
        key = jax.random.PRNGKey(5)
        got = svc.transform(qdocs[:4], key=key, timeout=60)
    import dataclasses
    m1 = dataclasses.replace(model, W=W1)
    with LDAService(m1, small_cfg(n_replicas=2)) as ref:
        want = ref.transform(qdocs[:4], key=key, timeout=60)
    assert np.array_equal(got, want)


@pytest.mark.chaos
def test_backpressure_sheds_load_when_saturated(model, qdocs):
    cfg = small_cfg(n_replicas=1, queue_limit=4, max_delay_ms=0.5)
    with LDAService(model, cfg) as svc:
        svc.infer(qdocs[0], timeout=60)            # warm
        with chaos.active(chaos.FaultPlan(slow_replicas={0: 1.0})):
            saw_overload = False
            futs = []
            for i in range(200):
                try:
                    futs.append(svc.submit(qdocs[i % len(qdocs)]))
                except ServiceOverloaded:
                    saw_overload = True
                    break
                time.sleep(0.002)
            assert saw_overload, "bounded queue never shed load"
            for f in futs:                          # accepted work drains
                f.result(timeout=60)
        assert svc.stats()["rejected"] >= 1


# ---------------------------------------------------------------------------
# 5. metrics
# ---------------------------------------------------------------------------

def test_latency_histogram_percentiles():
    h = LatencyHistogram()
    for v in [0.001] * 98 + [0.5, 1.0]:
        h.record(v)
    assert h.n == 100
    p50, p99 = h.percentile(0.50), h.percentile(0.99)
    assert 0.0008 < p50 < 0.0013                   # log-bucket tolerance
    assert p99 >= 0.45
    assert h.percentile(1.0) == h.max == 1.0
    snap = h.snapshot_ms()
    assert snap["n"] == 100 and snap["p50_ms"] <= snap["p99_ms"]


def test_serve_metrics_snapshot_accounting():
    m = ServeMetrics()
    m.record_request(0.010)
    m.record_request(0.020)
    m.record_batch(n_real=2, n_slots=4, queue_depth=3)
    m.record_cache(hits=8, misses=2)
    m.record_refresh(staleness_steps=0.5, seq=4)
    m.record_rejected()
    s = m.snapshot()
    assert s["completed"] == 2 and s["rejected"] == 1
    assert s["batch_fill"] == 0.5
    assert s["queue_depth_peak"] == 3
    assert s["cache_hit_rate"] == 0.8
    assert s["refreshes"] == 1 and s["snapshot_seq"] == 4
    assert s["staleness_steps"] == 0.5
    assert s["latency"]["n"] == 2
