"""FrozenLDAModel serving tests (repro/lda/api.py fold-in inference).

The load-bearing properties:
  1. transform() is bit-reproducible under a fixed key, and the sweep key
     schedule is prefix-stable (n_sweeps=s reproduces the first s sweeps
     of any longer run).
  2. The fold-in sampler agrees with a float64 NumPy oracle, teacher-
     forced sweep by sweep (mismatches allowed only within a tiny margin
     of a CDF boundary — the f32-vs-f64 edge).
  3. score() on the training documents matches the trainer's evaluate()
     within tolerance: fold-in re-derives θ that the training D already
     encodes.
  4. A serving batch is ONE donated jit dispatch with zero host syncs:
     transform_batch runs under jax.transfer_guard("disallow") and
     consumes (donates) the batch's word_ids buffer.
  5. The artifact round-trips: save/load, export-from-state vs
     export-from-checkpoint-payload, and the vocab map survives.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.lda.api import FrozenLDAModel, LDAEngine
from repro.lda.corpus import synthetic_lda_corpus
from repro.lda.model import LDAConfig

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def raw_corpus():
    # raw (unrelabeled): the engine preps it, so word_map is exercised
    return synthetic_lda_corpus(0, n_docs=40, n_words=40, n_topics=4,
                                mean_doc_len=14)


@pytest.fixture(scope="module")
def engine(raw_corpus):
    eng = LDAEngine(raw_corpus,
                    LDAConfig(n_topics=8, tile_size=256, eval_every=5,
                              fused=True),
                    backend="single")
    eng.fit(15)
    return eng


@pytest.fixture(scope="module")
def model(engine):
    return engine.export()


@pytest.fixture(scope="module")
def held_docs():
    rng = np.random.default_rng(7)
    return [list(rng.integers(0, 40, 12)) for _ in range(6)]


# ---------------------------------------------------------------------------
# 1. reproducibility
# ---------------------------------------------------------------------------

def test_transform_bit_reproducible(model, held_docs):
    t1 = model.transform(held_docs, n_sweeps=6, seed=3)
    t2 = model.transform(held_docs, n_sweeps=6, seed=3)
    assert np.array_equal(t1, t2)
    assert t1.shape == (len(held_docs), model.n_topics)
    assert np.allclose(t1.sum(axis=1), 1.0, atol=1e-5)
    t3 = model.transform(held_docs, n_sweeps=6, seed=4)
    assert not np.array_equal(t1, t3), "different key must change θ"


def test_sweep_keys_prefix_stable(model, held_docs):
    """n_sweeps=0 returns the raw init; the init matches the documented
    key schedule (kinit from the first split) — the contract the oracle
    teacher-forcing below builds on."""
    key = jax.random.PRNGKey(5)
    b = model.prepare_batch(held_docs)
    n = int(b.word_ids.shape[0])
    t0 = np.asarray(model.transform_batch(
        model.prepare_batch(held_docs), key, n_sweeps=0)[2])
    kinit, _ = jax.random.split(key)
    expect = np.asarray(jax.random.randint(kinit, (n,), 0, model.n_topics,
                                           dtype=jnp.int32))
    assert np.array_equal(t0, expect)


# ---------------------------------------------------------------------------
# 2. the NumPy fold-in oracle
# ---------------------------------------------------------------------------

def test_fold_in_matches_numpy_oracle(model, held_docs):
    """Teacher-forced sweep-by-sweep: float64 exact three-branch sampling
    must reproduce the jit fold-in's topic draws (identical uniforms, the
    prefix-stable key schedule), except within ~1e-4·total of a CDF
    boundary where f32 and f64 may legitimately disagree."""
    K, V = model.n_topics, model.n_words
    alpha, beta = float(model.alpha), float(model.beta)
    key = jax.random.PRNGKey(5)
    b0 = model.prepare_batch(held_docs)
    wid = np.asarray(b0.word_ids)
    did = np.asarray(b0.doc_ids)
    msk = np.asarray(b0.mask)
    n, B = wid.shape[0], b0.n_docs

    W = model.W.astype(np.float64)
    W_hat = (W + beta) / (W.sum(0) + V * beta)           # the frozen φ
    _, ksweep = jax.random.split(key)

    prev = np.asarray(model.transform_batch(
        model.prepare_batch(held_docs), key, n_sweeps=0)[2])
    n_sweeps, mismatches, real = 3, 0, 0
    for s in range(n_sweeps):
        D = np.zeros((B, K), np.int64)
        np.add.at(D, (did, prev), msk)
        u = np.asarray(jax.random.uniform(
            jax.random.fold_in(ksweep, s), (n,),
            dtype=jnp.float32)).astype(np.float64)
        nxt = np.asarray(model.transform_batch(
            model.prepare_batch(held_docs), key, n_sweeps=s + 1)[2])
        for i in range(n):
            if not msk[i]:
                continue
            real += 1
            w = W_hat[wid[i]]
            k1 = int(np.argmax(w))
            drow = D[did[i]].astype(np.float64)
            mass = np.where(np.arange(K) == k1, 0.0, (drow + alpha) * w)
            m = w[k1] * (drow[k1] + alpha)
            cum = np.cumsum(mass)
            x = u[i] * (m + cum[-1])
            if x < m:
                topic = k1
            else:
                topic = int(min(np.searchsorted(cum, x - m, side="right"),
                                K - 1))
            if topic != nxt[i]:
                # only a CDF-boundary fp edge may disagree
                bounds = np.concatenate([[m], m + cum])
                margin = np.min(np.abs(x - bounds)) / (m + cum[-1])
                assert margin < 1e-4, (
                    f"sweep {s} token {i}: oracle {topic} vs jax "
                    f"{int(nxt[i])} with margin {margin:.2e}")
                mismatches += 1
        prev = nxt
    assert real >= 100, "oracle corpus too small to mean anything"
    assert mismatches <= max(1, real // 100), \
        f"{mismatches}/{real} boundary mismatches is too many"


# ---------------------------------------------------------------------------
# 3. score() vs evaluate()
# ---------------------------------------------------------------------------

def test_score_on_training_docs_matches_evaluate(engine, model, raw_corpus):
    """Fold-in re-derives what training already knows: LLPT from
    transform()'s θ on the training docs lands within tolerance of the
    trainer's evaluate() (measured gap ~0.007 bits; bound 0.15)."""
    ev = engine.score()
    # raw_corpus.documents() is in the ORIGINAL vocab — the model remaps
    sc = model.score(raw_corpus.documents(), n_sweeps=30, seed=0)
    assert abs(ev - sc) < 0.15, (ev, sc)


# ---------------------------------------------------------------------------
# 4. one donated dispatch, zero host syncs
# ---------------------------------------------------------------------------

def test_transform_batch_no_host_syncs_and_donated(model, held_docs):
    key = jax.random.PRNGKey(1)
    # warm the compile cache for this (B, L, sweeps) signature
    model.transform_batch(model.prepare_batch(held_docs), key, n_sweeps=4)
    batch = model.prepare_batch(held_docs)
    with jax.transfer_guard("disallow"):      # any host sync would raise
        out = model.transform_batch(batch, key, n_sweeps=4)
        jax.block_until_ready(out)
    assert batch.word_ids.is_deleted(), \
        "word_ids must be DONATED to the dispatch"
    theta = np.asarray(out[0])                # readback after the guard
    assert np.allclose(theta.sum(axis=1), 1.0, atol=1e-5)
    skips = np.asarray(out[4])
    assert skips.shape == (4,) and np.all((skips >= 0) & (skips <= 1))


# ---------------------------------------------------------------------------
# 5. the artifact round-trips
# ---------------------------------------------------------------------------

def test_save_load_roundtrip(model, held_docs, tmp_path):
    path = str(tmp_path / "frozen.npz")
    model.save(path)
    back = FrozenLDAModel.load(path)
    assert np.array_equal(back.W, model.W)
    assert back.alpha == model.alpha and back.beta == model.beta
    assert np.array_equal(back.word_map, model.word_map)
    t1 = model.transform(held_docs, n_sweeps=5, seed=2)
    t2 = back.transform(held_docs, n_sweeps=5, seed=2)
    assert np.array_equal(t1, t2), "loaded artifact must serve identically"


def test_export_from_checkpoint_payload(engine, model):
    """FrozenLDAModel.from_payload(canonical checkpoint) rebuilds the same
    W the live-state export carries — counts are derived state."""
    m2 = FrozenLDAModel.from_payload(engine.host_payload(), engine.corpus,
                                     engine.config,
                                     word_map=engine.word_map)
    assert np.array_equal(m2.W, model.W)


def test_top_words_speak_original_vocab(engine, model):
    top = model.top_words(5)
    assert top.shape == (model.n_topics, 5)
    assert top.min() >= 0 and top.max() < model.n_words
    # invert the check: mapping the reported (original) ids through the
    # engine's word_map must reproduce the model-space argsort
    wm = np.asarray(engine.word_map)
    model_space = np.argsort(-model.W, axis=0, kind="stable")[:5].T
    assert np.array_equal(wm[top], model_space)


def test_prepare_batch_validation(model):
    with pytest.raises(ValueError, match="at least one"):
        model.prepare_batch([])
    with pytest.raises(ValueError, match="vocabulary"):
        model.prepare_batch([[0, 1, model.n_words + 3]])


def test_from_state_constructor(engine):
    m = FrozenLDAModel.from_state(engine.state, engine.config,
                                  word_map=engine.word_map)
    assert np.array_equal(m.W, np.asarray(engine.state.W))


# ---------------------------------------------------------------------------
# 6. mid-epoch streamed payloads are not servable
# ---------------------------------------------------------------------------

def test_from_payload_rejects_mid_epoch_streamed_checkpoint(raw_corpus):
    """A mid-epoch stream payload's ``topics_global`` is rewound to the
    epoch start (the open epoch's samples live in stream_done_topics), so
    freezing it would silently serve counts up to one epoch stale —
    from_payload must refuse, naming both recovery recipes."""
    from repro.lda.trainer import LDATrainer
    cfg = LDAConfig(n_topics=8, tile_size=256,
                    corpus_residency="streamed", stream_shards=4)
    tr = LDATrainer(raw_corpus, cfg, _from_engine=True)
    pipe = tr.fused_pipeline()
    ss = pipe.run_shards(pipe.from_lda_state(tr.init_state()), 2)
    assert ss.cursor == 2                      # genuinely mid-epoch
    payload = pipe.stream_payload(ss)
    with pytest.raises(ValueError, match="MID-EPOCH") as exc:
        FrozenLDAModel.from_payload(payload, raw_corpus, cfg)
    msg = str(exc.value)
    assert "engine.export()" in msg            # recipe 1: finish + freeze
    assert "publish_serving" in msg            # recipe 2: bounded staleness

    # the SAME pipeline's epoch-boundary payload freezes fine
    ss, _, _ = pipe.run_fused(ss, 1)           # finish the open epoch
    assert ss.cursor == 0
    m = FrozenLDAModel.from_payload(pipe.stream_payload(ss), raw_corpus,
                                    cfg)
    assert m.n_words == raw_corpus.n_words
    assert int(m.W.sum()) == raw_corpus.n_tokens
