"""Sparsity-aware format tests (paper §IV): pair packing, bucketed ELL rows,
hybrid W, and the Table-I byte model direction."""

import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st

from repro.core import sparse
from repro.lda.corpus import zipf_corpus, relabel_by_frequency


@settings(max_examples=50, deadline=None)
@given(idx=st.integers(0, 65_535), val=st.integers(0, 65_535))
def test_pack_unpack_roundtrip(idx, val):
    p = sparse.pack_pairs(jnp.full((1,), idx, jnp.int32),
                          jnp.full((1,), val, jnp.int32))
    i, v = sparse.unpack_pairs(p)
    assert int(i[0]) == idx and int(v[0]) == val


def test_pack_unpack_array_roundtrip():
    rng = np.random.default_rng(0)
    idx = rng.integers(0, 65_536, (10, 7)).astype(np.int32)
    val = rng.integers(0, 65_536, (10, 7)).astype(np.int32)
    p = sparse.pack_pairs(jnp.asarray(idx), jnp.asarray(val))
    i, v = sparse.unpack_pairs(p)
    assert np.array_equal(np.asarray(i), idx)
    assert np.array_equal(np.asarray(v), val)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000))
def test_build_densify_roundtrip(seed):
    rng = np.random.default_rng(seed)
    K = 24
    dense = np.zeros((6, K), np.int32)
    for r in range(6):
        nnz = rng.integers(0, 9)
        cols = rng.choice(K, nnz, replace=False)
        dense[r, cols] = rng.integers(1, 100, nnz)
    packed = sparse.build_sparse_rows(jnp.asarray(dense), capacity=10)
    back = sparse.densify_rows(packed, K)
    assert np.array_equal(np.asarray(back), dense)


def test_sparse_lookup():
    dense = jnp.asarray([[0, 5, 0, 7, 0, 0, 0, 0]], jnp.int32)
    packed = sparse.build_sparse_rows(dense, capacity=3)
    assert int(sparse.sparse_lookup(packed[0], jnp.int32(1))) == 5
    assert int(sparse.sparse_lookup(packed[0], jnp.int32(3))) == 7
    assert int(sparse.sparse_lookup(packed[0], jnp.int32(0))) == 0


def test_bucket_plan_covers_and_bounds():
    nnz = np.array([500, 400, 100, 90, 33, 12, 9, 3, 2, 1, 1, 1])
    plans = sparse.bucket_plan(nnz, max_capacity=512, min_capacity=4)
    covered = 0
    for (s, e, cap) in plans:
        assert np.all(nnz[s:e] <= cap), (s, e, cap)
        assert s == covered
        covered = e
    assert covered == len(nnz)


def test_hybrid_w_roundtrip(skewed_corpus):
    corpus = skewed_corpus
    K = 32
    rng = np.random.default_rng(2)
    W = np.zeros((corpus.n_words, K), np.int32)
    # counts consistent with word_token_counts (row sum == token count)
    for v in range(corpus.n_words):
        c = int(corpus.word_token_counts[v])
        if c:
            ks = rng.integers(0, K, c)
            np.add.at(W[v], ks, 1)
    hw = sparse.build_hybrid_w(jnp.asarray(W), corpus.word_token_counts,
                               threshold=K)
    back = np.asarray(hw.densify(K))
    assert np.array_equal(back, W)
    # dense split point honors the paper's heuristic
    assert np.all(corpus.word_token_counts[:hw.v_dense] >= K)
    if hw.v_dense < corpus.n_words:
        assert np.all(corpus.word_token_counts[hw.v_dense:] < K)


def test_hybrid_beats_dense_and_sparse_at_large_k():
    """Table I / Fig 13 direction: hybrid ≤ min(dense, all-sparse) at large K."""
    c = zipf_corpus(3, n_docs=400, n_words=2000, exponent=1.4, mean_doc_len=80)
    c, _ = relabel_by_frequency(c)
    counts = c.word_token_counts
    for K in (256, 1024, 4096):
        dense_b = sparse.bytes_dense(c.n_words, K)
        all_sparse_b = sparse.bytes_bucketed(
            np.minimum(counts, K), max_capacity=K)
        hybrid = sparse.bytes_hybrid(counts, K)
        assert hybrid["total"] <= dense_b
        assert hybrid["total"] <= all_sparse_b * 1.01  # ties allowed
    # and savings grow with K (the paper's headline)
    h1 = sparse.bytes_hybrid(counts, 256)["total"] / sparse.bytes_dense(c.n_words, 256)
    h2 = sparse.bytes_hybrid(counts, 4096)["total"] / sparse.bytes_dense(c.n_words, 4096)
    assert h2 < h1
