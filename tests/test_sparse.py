"""Sparsity-aware format tests (paper §IV): pair packing, bucketed ELL rows,
hybrid W, and the Table-I byte model direction."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import sparse
from repro.lda.corpus import zipf_corpus, relabel_by_frequency


@settings(max_examples=50, deadline=None)
@given(idx=st.integers(0, 65_535), val=st.integers(0, 65_535))
def test_pack_unpack_roundtrip(idx, val):
    p = sparse.pack_pairs(jnp.full((1,), idx, jnp.int32),
                          jnp.full((1,), val, jnp.int32))
    i, v = sparse.unpack_pairs(p)
    assert int(i[0]) == idx and int(v[0]) == val


def test_pack_unpack_array_roundtrip():
    rng = np.random.default_rng(0)
    idx = rng.integers(0, 65_536, (10, 7)).astype(np.int32)
    val = rng.integers(0, 65_536, (10, 7)).astype(np.int32)
    p = sparse.pack_pairs(jnp.asarray(idx), jnp.asarray(val))
    i, v = sparse.unpack_pairs(p)
    assert np.array_equal(np.asarray(i), idx)
    assert np.array_equal(np.asarray(v), val)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000))
def test_build_densify_roundtrip(seed):
    rng = np.random.default_rng(seed)
    K = 24
    dense = np.zeros((6, K), np.int32)
    for r in range(6):
        nnz = rng.integers(0, 9)
        cols = rng.choice(K, nnz, replace=False)
        dense[r, cols] = rng.integers(1, 100, nnz)
    packed = sparse.build_sparse_rows(jnp.asarray(dense), capacity=10)
    back = sparse.densify_rows(packed, K)
    assert np.array_equal(np.asarray(back), dense)


def test_sparse_lookup():
    dense = jnp.asarray([[0, 5, 0, 7, 0, 0, 0, 0]], jnp.int32)
    packed = sparse.build_sparse_rows(dense, capacity=3)
    assert int(sparse.sparse_lookup(packed[0], jnp.int32(1))) == 5
    assert int(sparse.sparse_lookup(packed[0], jnp.int32(3))) == 7
    assert int(sparse.sparse_lookup(packed[0], jnp.int32(0))) == 0


def test_bucket_plan_covers_and_bounds():
    nnz = np.array([500, 400, 100, 90, 33, 12, 9, 3, 2, 1, 1, 1])
    plans = sparse.bucket_plan(nnz, max_capacity=512, min_capacity=4)
    covered = 0
    for (s, e, cap) in plans:
        assert np.all(nnz[s:e] <= cap), (s, e, cap)
        assert s == covered
        covered = e
    assert covered == len(nnz)


def test_hybrid_w_roundtrip(skewed_corpus):
    corpus = skewed_corpus
    K = 32
    rng = np.random.default_rng(2)
    W = np.zeros((corpus.n_words, K), np.int32)
    # counts consistent with word_token_counts (row sum == token count)
    for v in range(corpus.n_words):
        c = int(corpus.word_token_counts[v])
        if c:
            ks = rng.integers(0, K, c)
            np.add.at(W[v], ks, 1)
    hw = sparse.build_hybrid_w(jnp.asarray(W), corpus.word_token_counts,
                               threshold=K)
    back = np.asarray(hw.densify(K))
    assert np.array_equal(back, W)
    # dense split point honors the paper's heuristic
    assert np.all(corpus.word_token_counts[:hw.v_dense] >= K)
    if hw.v_dense < corpus.n_words:
        assert np.all(corpus.word_token_counts[hw.v_dense:] < K)


# ---------------------------------------------------------------------------
# incremental packed-ELL ops (the live-state update path)
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_ell_insert_remove_sequences_match_dense(seed):
    """Random ±1 sequences on bucketed rows == the dense scatter oracle,
    including remove-to-zero slot reclamation (freed slots get reused)."""
    rng = np.random.default_rng(seed)
    R, K = 6, 20
    L = K                       # nnz <= K: overflow impossible by bound
    dense = np.zeros((R, K), np.int32)
    packed = sparse.build_sparse_rows(jnp.asarray(dense), L)
    for _ in range(8):
        C = 24
        rows = rng.integers(0, R, C).astype(np.int32)
        add = rng.integers(0, K, C).astype(np.int32)
        sub = np.zeros(C, np.int32)
        w_add = rng.integers(0, 2, C).astype(np.int32)
        w_sub = np.zeros(C, np.int32)
        budget = dense.copy()
        for i in range(C):
            nz = np.nonzero(budget[rows[i]])[0]
            if len(nz) and rng.random() < 0.7:
                sub[i] = rng.choice(nz)
                w_sub[i] = 1
                budget[rows[i], sub[i]] -= 1
        packed, miss = sparse.ell_sub_one(packed, jnp.asarray(rows),
                                          jnp.asarray(sub),
                                          jnp.asarray(w_sub))
        packed, over = sparse.ell_add_one(packed, jnp.asarray(rows),
                                          jnp.asarray(add),
                                          jnp.asarray(w_add))
        np.subtract.at(dense, (rows[w_sub > 0], sub[w_sub > 0]), 1)
        np.add.at(dense, (rows[w_add > 0], add[w_add > 0]), 1)
        assert int(miss) == 0 and int(over) == 0
        back = np.asarray(sparse.densify_rows(packed, K))
        assert np.array_equal(back, dense)
    # slot reclamation: remove EVERYTHING — every slot must read as free
    # (val == 0), and the row must accept a full load of fresh columns.
    r0 = 0
    nz = np.nonzero(dense[r0])[0]
    for c in nz:
        reps = int(dense[r0, c])
        packed, miss = sparse.ell_sub_one(
            packed, jnp.full((reps,), r0, jnp.int32),
            jnp.full((reps,), c, jnp.int32), jnp.ones(reps, jnp.int32))
        assert int(miss) == 0
    _, val = sparse.unpack_pairs(packed[r0])
    assert int(jnp.sum(val)) == 0
    fresh = np.arange(K, dtype=np.int32)
    packed, over = sparse.ell_add_one(
        packed, jnp.full((K,), r0, jnp.int32), jnp.asarray(fresh),
        jnp.ones(K, jnp.int32))
    assert int(over) == 0          # all K columns fit: slots were reclaimed
    assert np.array_equal(
        np.asarray(sparse.densify_rows(packed, K))[r0], np.ones(K))


@settings(max_examples=30, deadline=None)
@given(col=st.integers(0, 65_535), count=st.integers(1, 60_000))
def test_ell_ops_full_16bit_index_range(col, count):
    """Slot lookups/updates stay correct across the full 16-bit idx range
    (unsigned unpack: idx >= 32768 must not sign-extend)."""
    K = 65_536
    packed = jnp.zeros((1, 4), jnp.int32)
    rows = jnp.zeros((3,), jnp.int32)
    cols = jnp.full((3,), col, jnp.int32)
    packed, over = sparse.ell_add_one(packed, rows, cols,
                                      jnp.ones(3, jnp.int32))
    assert int(over) == 0
    # bulk-load the count via a direct pack, then one ±1 round trip
    packed = packed.at[0, 0].set(int(sparse.pack_pairs(
        jnp.int32(col), jnp.int32(count))))
    packed = packed.at[0, 1:].set(0)
    assert int(sparse.ell_lookup(packed, rows[:1], cols[:1])[0]) == count
    packed, _ = sparse.ell_sub_one(packed, rows[:1], cols[:1],
                                   jnp.ones(1, jnp.int32))
    assert int(sparse.ell_lookup(packed, rows[:1], cols[:1])[0]) == count - 1


def test_ell_apply_deltas_duplicates_match_scatter_oracle():
    """Duplicate (row, col) updates in ONE batch accumulate exactly."""
    rng = np.random.default_rng(5)
    R, K = 4, 12
    dense = rng.integers(0, 4, (R, K)).astype(np.int32)
    packed = sparse.build_sparse_rows(jnp.asarray(dense), K)
    C = 40
    rows = rng.integers(0, R, C).astype(np.int32)
    new = rng.integers(0, K, C).astype(np.int32)
    old = np.zeros(C, np.int32)
    w = np.zeros(C, np.int32)
    budget = dense.copy()
    for i in range(C):
        nz = np.nonzero(budget[rows[i]])[0]
        if len(nz):
            old[i] = rng.choice(nz)
            w[i] = 1
            budget[rows[i], old[i]] -= 1
    packed, dropped = sparse.ell_apply_deltas(
        packed, jnp.asarray(rows), jnp.asarray(old), jnp.asarray(new),
        jnp.asarray(w))
    oracle = dense.copy()
    np.subtract.at(oracle, (rows[w > 0], old[w > 0]), 1)
    np.add.at(oracle, (rows[w > 0], new[w > 0]), 1)
    assert int(dropped) == 0
    assert np.array_equal(np.asarray(sparse.densify_rows(packed, K)), oracle)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_pack_rows_sorted_roundtrip(seed):
    """Sorted pack <-> densify round-trips exactly; slots sorted by col."""
    rng = np.random.default_rng(seed)
    R, K, L = 7, 40, 24
    dense = np.zeros((R, K), np.int32)
    for r in range(R):
        cols = rng.choice(K, rng.integers(0, L), replace=False)
        dense[r, cols] = rng.integers(1, 100, len(cols))
    packed, over = sparse.pack_rows_sorted(jnp.asarray(dense), L)
    assert int(over) == 0
    idx, val = sparse.unpack_pairs(packed)
    assert np.all(np.diff(np.asarray(idx), axis=1) >= 0)  # sorted invariant
    back = sparse.densify_rows_sorted(packed, K)
    assert np.array_equal(np.asarray(back), dense)
    # the order-agnostic densify agrees too (EMPTY_IDX pads drop)
    back2 = sparse.densify_rows(packed, K)
    assert np.array_equal(np.asarray(back2), dense)


def test_pack_rows_sorted_overflow_counted():
    dense = jnp.asarray([[1, 2, 3, 4, 0, 0]], jnp.int32)
    packed, over = sparse.pack_rows_sorted(dense, 2)
    assert int(over) == 2                     # two nonzeros did not fit
    back = np.asarray(sparse.densify_rows_sorted(packed, 6))
    assert np.array_equal(back[0], [1, 2, 0, 0, 0, 0])  # lowest cols kept


def test_ell_slot_apply_matches_dense_on_live_columns():
    rng = np.random.default_rng(9)
    R, K, L = 5, 16, 16
    dense = rng.integers(0, 5, (R, K)).astype(np.int32)
    packed, _ = sparse.pack_rows_sorted(jnp.asarray(dense), L)
    # a delta that only touches live columns (incl. driving some to zero)
    delta = np.where(dense > 0, rng.integers(-1, 3, (R, K)), 0)
    delta = np.maximum(delta, -dense).astype(np.int32)
    packed = sparse.ell_slot_apply(packed, jnp.asarray(delta))
    back = np.asarray(sparse.densify_rows(packed, K))
    assert np.array_equal(back, dense + delta)


def test_ell_overflow_is_counted_not_corrupting():
    """Inserts beyond capacity drop and report; live slots stay intact."""
    packed = sparse.build_sparse_rows(jnp.zeros((1, 8), jnp.int32), 2)
    rows = jnp.zeros((3,), jnp.int32)
    packed, over = sparse.ell_add_one(
        packed, rows, jnp.asarray([1, 2, 3], jnp.int32),
        jnp.ones(3, jnp.int32))
    assert int(over) == 1
    back = np.asarray(sparse.densify_rows(packed, 8))
    assert back.sum() == 2 and back.max() == 1


def test_bucket_plan_rejects_unsorted_rows():
    with pytest.raises(ValueError, match="relabel"):
        sparse.bucket_plan(np.array([1, 5, 3]), max_capacity=8)


def test_build_hybrid_w_rejects_unsorted_counts():
    W = jnp.zeros((3, 4), jnp.int32)
    with pytest.raises(ValueError, match="relabel"):
        sparse.build_hybrid_w(W, np.array([1, 9, 2]), threshold=4)


def test_hybrid_beats_dense_and_sparse_at_large_k():
    """Table I / Fig 13 direction: hybrid ≤ min(dense, all-sparse) at large K."""
    c = zipf_corpus(3, n_docs=400, n_words=2000, exponent=1.4, mean_doc_len=80)
    c, _ = relabel_by_frequency(c)
    counts = c.word_token_counts
    for K in (256, 1024, 4096):
        dense_b = sparse.bytes_dense(c.n_words, K)
        all_sparse_b = sparse.bytes_bucketed(
            np.minimum(counts, K), max_capacity=K)
        hybrid = sparse.bytes_hybrid(counts, K)
        assert hybrid["total"] <= dense_b
        assert hybrid["total"] <= all_sparse_b * 1.01  # ties allowed
    # and savings grow with K (the paper's headline)
    h1 = sparse.bytes_hybrid(counts, 256)["total"] / sparse.bytes_dense(c.n_words, 256)
    h2 = sparse.bytes_hybrid(counts, 4096)["total"] / sparse.bytes_dense(c.n_words, 4096)
    assert h2 < h1
