"""Disk-native corpus store tests (repro.lda.storage, DESIGN.md SS14).

The load-bearing properties:
  1. write -> read round-trips BITWISE for arbitrary corpora (hypothesis:
     empty docs, 0-token words, single-doc shards, max-vocab ids), both
     shard-by-shard through ``CorpusStore.read_shard`` and wholesale
     through ``ShardedCorpus.from_store``.
  2. Every way a store can rot on disk — missing shard file, truncated
     bytes, flipped bit, wrong-manifest shard, torn manifest, future
     format version — surfaces as a loud, shard-indexed error instead of
     silently poisoning counts.
  3. The manifest is written LAST, so a torn write leaves a directory
     that refuses to open.
"""

import json
import os

import numpy as np
import pytest

from tests._hyp import given, settings, st
from repro.lda.corpus import ShardedCorpus, from_documents, shard_stream
from repro.lda.invariants import ShardCorruptionError
from repro.lda.storage import (FORMAT_VERSION, MANIFEST_NAME, META_NAME,
                               CorpusStore)


def _docs_strategy():
    # max_value=29 with n_words=30 exercises the max-vocab-id edge; empty
    # inner lists give 0-length docs and (typically) 0-token words
    return st.lists(
        st.lists(st.integers(min_value=0, max_value=29), min_size=0,
                 max_size=12),
        min_size=1, max_size=25)


def _store_of(docs, n_shards, tmp_path, multiple=8):
    corpus = from_documents([np.asarray(d, np.int64) for d in docs], 30)
    sc = shard_stream(corpus, n_shards, multiple=multiple)
    return sc, sc.to_store(str(tmp_path / "store"))


# ---------------------------------------------------------------------------
# 1. round-trip (property tests)
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(docs=_docs_strategy(), n_shards=st.integers(min_value=1, max_value=6))
def test_store_roundtrip_bitwise(docs, n_shards, tmp_path_factory):
    tmp_path = tmp_path_factory.mktemp("store")
    sc, store = _store_of(docs, n_shards, tmp_path)
    # manifest metadata mirrors the stream exactly
    assert (store.n_shards, store.shard_len, store.n_padded,
            store.n_tokens, store.n_words, store.n_docs) == \
        (sc.n_shards, sc.shard_len, sc.n_padded, sc.n_tokens,
         sc.n_words, sc.n_docs)
    assert np.array_equal(store.first_word, sc.first_word)
    assert np.array_equal(store.last_word, sc.last_word)
    assert np.array_equal(store.shard_checksums, sc.shard_checksums)
    assert np.array_equal(store.real_per_shard, sc.real_per_shard)
    # shard payloads round-trip bitwise
    for s in range(sc.n_shards):
        w, d, m = store.read_shard(s)
        assert np.array_equal(w, sc.word_ids[s])
        assert np.array_equal(d, sc.doc_ids[s])
        assert np.array_equal(m, sc.mask[s])
    # and wholesale through from_store (validates internally)
    back = ShardedCorpus.from_store(store)
    assert np.array_equal(back.word_ids, sc.word_ids)
    assert np.array_equal(back.doc_ids, sc.doc_ids)
    assert np.array_equal(back.mask, sc.mask)
    # corpus-level metadata folds to the true histograms
    meta = store.corpus_meta()
    corpus = from_documents([np.asarray(d, np.int64) for d in docs], 30)
    assert np.array_equal(meta.word_token_counts,
                          np.asarray(corpus.word_token_counts, np.int64))
    assert np.array_equal(meta.doc_lengths,
                          np.asarray(corpus.doc_lengths, np.int64))


@pytest.mark.parametrize("seed,n_shards,multiple", [
    (0, 1, 1), (1, 2, 8), (2, 3, 8), (3, 6, 32), (4, 4, 1),
])
def test_store_roundtrip_bitwise_seeded(seed, n_shards, multiple, tmp_path):
    """Deterministic fallback for the hypothesis round-trip property
    (runs even without hypothesis installed): random corpora with empty
    docs, 0-token words, and max-vocab ids, across shard geometries."""
    rng = np.random.default_rng(seed)
    docs = [rng.integers(0, 30, size=rng.integers(0, 12)).tolist()
            for _ in range(rng.integers(1, 25))]
    docs[0] = docs[0] + [29]                # pin the max-vocab-id edge
    sc, store = _store_of(docs, n_shards, tmp_path, multiple=multiple)
    back = ShardedCorpus.from_store(store)
    assert np.array_equal(back.word_ids, sc.word_ids)
    assert np.array_equal(back.doc_ids, sc.doc_ids)
    assert np.array_equal(back.mask, sc.mask)
    assert np.array_equal(store.shard_checksums, sc.shard_checksums)
    back.validate(deep=True)


def test_store_single_doc_single_shard(tmp_path):
    """Degenerate geometry: one doc, one shard, vocab id at the max."""
    sc, store = _store_of([[29, 29, 0]], 1, tmp_path, multiple=1)
    w, d, m = store.read_shard(0)
    assert np.array_equal(w[m > 0], np.sort([29, 29, 0]))
    assert (d[m > 0] == 0).all()


def test_store_open_by_path_equals_returned_handle(tmp_path):
    sc, store = _store_of([[1, 2, 3], [2, 2]], 2, tmp_path)
    again = CorpusStore.open(store.path)
    for s in range(sc.n_shards):
        a, b = store.read_shard(s), again.read_shard(s)
        assert all(np.array_equal(x, y) for x, y in zip(a, b))


# ---------------------------------------------------------------------------
# 2. corruption surfaces loudly, naming the shard
# ---------------------------------------------------------------------------

def _good_store(tmp_path):
    rng = np.random.default_rng(0)
    docs = [rng.integers(0, 30, size=rng.integers(1, 12)).tolist()
            for _ in range(20)]
    return _store_of(docs, 3, tmp_path)


def test_missing_shard_file_names_the_shard(tmp_path):
    sc, store = _good_store(tmp_path)
    os.remove(os.path.join(store.path, store.shard_files[1]))
    with pytest.raises(ShardCorruptionError, match="shard 1 is missing"):
        store.read_shard(1)
    store.read_shard(0)     # neighbors stay readable


def test_truncated_shard_file_names_the_shard(tmp_path):
    sc, store = _good_store(tmp_path)
    path = os.path.join(store.path, store.shard_files[2])
    with open(path, "r+b") as f:
        f.truncate(os.path.getsize(path) // 2)
    with pytest.raises(ShardCorruptionError, match="shard 2"):
        store.read_shard(2)


def test_bit_flipped_shard_fails_crc(tmp_path):
    """A single flipped PAYLOAD bit that still parses as an npz must be
    caught by the crc32 — the zip container's own checks are not the
    defense layer."""
    sc, store = _good_store(tmp_path)
    w, d, m = store.read_shard(0)
    w = w.copy()
    w[0] ^= 1
    np.savez(os.path.join(store.path, store.shard_files[0]),
             word_ids=w, doc_ids=d, mask=m)
    with pytest.raises(ShardCorruptionError, match="shard 0.*crc32"):
        store.read_shard(0)


def test_foreign_shard_fails_shape_or_crc(tmp_path):
    """A shard file from a different store (wrong length) is rejected."""
    sc, store = _good_store(tmp_path)
    np.savez(os.path.join(store.path, store.shard_files[1]),
             word_ids=np.zeros(4, np.int32), doc_ids=np.zeros(4, np.int32),
             mask=np.zeros(4, np.int32))
    with pytest.raises(ShardCorruptionError, match="shard 1"):
        store.read_shard(1)


def test_read_shard_out_of_range(tmp_path):
    sc, store = _good_store(tmp_path)
    with pytest.raises(IndexError, match="shard 3 out of range"):
        store.read_shard(3)


def test_from_store_surfaces_corruption(tmp_path):
    sc, store = _good_store(tmp_path)
    os.remove(os.path.join(store.path, store.shard_files[0]))
    with pytest.raises(ShardCorruptionError, match="shard 0"):
        ShardedCorpus.from_store(store.path)


# ---------------------------------------------------------------------------
# 3. manifest integrity (torn writes refuse to open)
# ---------------------------------------------------------------------------

def test_missing_manifest_is_not_a_store(tmp_path):
    with pytest.raises(FileNotFoundError, match="no corpus store"):
        CorpusStore.open(str(tmp_path / "nowhere"))


def test_torn_manifest_refuses_to_open(tmp_path):
    sc, store = _good_store(tmp_path)
    path = os.path.join(store.path, MANIFEST_NAME)
    with open(path, "r+", encoding="utf-8") as f:
        body = f.read()
        f.seek(0)
        f.truncate()
        f.write(body[:len(body) // 2])      # torn mid-write
    with pytest.raises(ValueError, match="torn mid-write"):
        CorpusStore.open(store.path)


def test_future_format_version_refuses_to_open(tmp_path):
    sc, store = _good_store(tmp_path)
    path = os.path.join(store.path, MANIFEST_NAME)
    with open(path, encoding="utf-8") as f:
        manifest = json.load(f)
    manifest["format_version"] = FORMAT_VERSION + 1
    with open(path, "w", encoding="utf-8") as f:
        json.dump(manifest, f)
    with pytest.raises(ValueError, match="format_version"):
        CorpusStore.open(store.path)


def test_inconsistent_manifest_refuses_to_open(tmp_path):
    sc, store = _good_store(tmp_path)
    path = os.path.join(store.path, MANIFEST_NAME)
    with open(path, encoding="utf-8") as f:
        manifest = json.load(f)
    manifest["n_shards"] = 99               # disagrees with shard list
    with open(path, "w", encoding="utf-8") as f:
        json.dump(manifest, f)
    with pytest.raises(ValueError, match="inconsistent"):
        CorpusStore.open(store.path)


def test_missing_meta_npz_fails_lazily_with_context(tmp_path):
    sc, store = _good_store(tmp_path)
    os.remove(os.path.join(store.path, META_NAME))
    store2 = CorpusStore.open(store.path)   # opens: meta is lazy
    with pytest.raises(ValueError, match=META_NAME):
        store2.corpus_meta()
