"""Out-of-core streaming tests (corpus_residency="streamed", DESIGN.md SS10).

The load-bearing properties:
  1. ``shard_stream`` shards exactly cover the padded token stream — no
     token lost or duplicated — and every shard's word-run metadata and
     inverted-index slice are consistent with its token slice
     (hypothesis property tests over random corpora).
  2. Streamed training is BITWISE equal to the resident fused path for
     dense × hybrid formats on the single-host backend and for
     dense × hybrid on the distributed backend, and composes with
     ``balance="tiles"`` and ``impl="pallas"`` unchanged.
  3. Mid-epoch checkpoints (stream_cursor / stream_done_topics) restore
     into a fresh pipeline and continue bit-identically, through the
     pipeline, the CheckpointManager npz round-trip, and the engine.
  4. The residency auto-policy streams exactly when estimated token
     bytes exceed the budget, and the shard planner respects the
     double-buffer window math.
"""

import numpy as np
import pytest

import jax

from tests._hyp import given, settings, st
from repro.lda.corpus import pad_corpus, shard_stream
from repro.lda.model import LDAConfig
from repro.lda.trainer import LDATrainer
from repro.train.lda_step import (STREAM_BYTES_PER_TOKEN,
                                  plan_stream_shards, resolve_residency)

jax.config.update("jax_platform_name", "cpu")


def _docs_strategy():
    return st.lists(
        st.lists(st.integers(min_value=0, max_value=29), min_size=0,
                 max_size=12),
        min_size=1, max_size=25)


# ---------------------------------------------------------------------------
# 1. ShardedCorpus invariants (property tests)
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(docs=_docs_strategy(),
       n_shards=st.integers(min_value=1, max_value=6),
       multiple=st.sampled_from([1, 8, 32]))
def test_shard_stream_exactly_covers_t(docs, n_shards, multiple):
    """No token lost/duplicated: the masked shard slots, in shard order,
    are exactly the padded stream's real tokens, which are exactly T."""
    from repro.lda.corpus import from_documents
    corpus = from_documents([np.asarray(d, np.int64) for d in docs], 30)
    sc = shard_stream(corpus, n_shards, multiple=multiple)
    sc.validate(deep=True)      # incl. the lazy inverted-index slices
    padded, mask = pad_corpus(corpus, multiple)
    assert sc.n_padded == padded.n_tokens
    assert sc.shard_len % multiple == 0
    flat_w = sc.word_ids.reshape(-1)
    flat_d = sc.doc_ids.reshape(-1)
    flat_m = sc.mask.reshape(-1)
    sel = flat_m > 0
    assert np.array_equal(flat_w[sel], corpus.word_ids)
    assert np.array_equal(flat_d[sel], corpus.doc_ids)
    assert int(sc.real_per_shard.sum()) == corpus.n_tokens


@settings(max_examples=40, deadline=None)
@given(docs=_docs_strategy(), n_shards=st.integers(min_value=1, max_value=6))
def test_shard_stream_word_runs_match_slices(docs, n_shards):
    """Per-shard word-run metadata (first/last word, word_offsets CSR)
    and the inverted-index slice agree with the shard's real tokens."""
    from repro.lda.corpus import from_documents
    corpus = from_documents([np.asarray(d, np.int64) for d in docs], 30)
    sc = shard_stream(corpus, n_shards)
    for s in range(sc.n_shards):
        real = int(sc.real_per_shard[s])
        w = sc.word_ids[s, :real]
        counts = np.diff(sc.word_offsets[s])
        assert np.array_equal(counts,
                              np.bincount(w, minlength=sc.n_words))
        if real:
            assert sc.first_word[s] == w.min() == w[0]
            assert sc.last_word[s] == w.max() == w[-1]
        else:
            assert sc.last_word[s] < sc.first_word[s]   # empty sentinel
        # inverted index: covers each real slot once, grouped by doc
        idx = sc.inv_token_idx[s, :real]
        assert np.array_equal(np.sort(idx), np.arange(real))
        offs = sc.inv_doc_offsets[s]
        docs_of = sc.doc_ids[s, :real]
        for d in range(sc.n_docs):
            assert np.all(docs_of[idx[offs[d]:offs[d + 1]]] == d)


def test_shard_stream_rejects_bad_shard_count(small_corpus):
    with pytest.raises(ValueError, match="n_shards"):
        shard_stream(small_corpus, 0)


# ---------------------------------------------------------------------------
# 2. streamed == resident, bit for bit (single host)
# ---------------------------------------------------------------------------

def _final_states(corpus, base_kw, stream_kw, n_iters=4):
    tr_r = LDATrainer(corpus, LDAConfig(**base_kw), _from_engine=True)
    pr = tr_r.fused_pipeline()
    fr = pr.from_lda_state(tr_r.init_state())
    fr, _, _ = pr.run_fused(fr, n_iters)
    ref = pr.to_lda_state(fr)
    tr_s = LDATrainer(corpus, LDAConfig(**base_kw, **stream_kw),
                      _from_engine=True)
    assert tr_s.residency == "streamed"
    ps = tr_s.fused_pipeline()
    ss = ps.from_lda_state(tr_s.init_state())
    ss, stats, n_surv = ps.run_fused(ss, n_iters)
    out = ps.to_lda_state(ss)
    return ref, out, stats, n_surv


def _assert_bitwise(corpus, ref, out):
    n = corpus.n_tokens
    assert np.array_equal(np.asarray(ref.topics)[:n],
                          np.asarray(out.topics)[:n])
    assert np.array_equal(np.asarray(ref.D), np.asarray(out.D))
    assert np.array_equal(np.asarray(ref.W), np.asarray(out.W))
    assert int(ref.iteration) == int(out.iteration)


@pytest.mark.parametrize("fmt,extra", [
    ("dense", {}),
    ("hybrid", {}),
    ("hybrid", {"tail_sampler": "sparse"}),
    ("dense", {"balance": "tiles"}),
    ("dense", {"impl": "pallas"}),
])
def test_streamed_equals_resident_single(small_corpus, fmt, extra):
    base = dict(n_topics=16, tile_size=512, format=fmt, **extra)
    stream = dict(corpus_residency="streamed", stream_shards=4)
    ref, out, stats, n_surv = _final_states(small_corpus, base, stream)
    _assert_bitwise(small_corpus, ref, out)
    assert np.asarray(stats.frac_skipped).shape == (4,)
    assert np.asarray(n_surv).shape == (4,)
    assert (np.asarray(n_surv) > 0).all()


def test_stream_shard_count_is_a_pure_perf_knob(small_corpus):
    """Any shard count produces identical bits (like survivor capacity)."""
    outs = []
    for shards in (2, 3, 7):
        tr = LDATrainer(small_corpus, LDAConfig(
            n_topics=16, tile_size=512, corpus_residency="streamed",
            stream_shards=shards), _from_engine=True)
        pipe = tr.fused_pipeline()
        ss, _, _ = pipe.run_fused(pipe.from_lda_state(tr.init_state()), 3)
        outs.append(np.asarray(pipe.to_lda_state(ss).topics)
                    [:small_corpus.n_tokens])
    assert np.array_equal(outs[0], outs[1])
    assert np.array_equal(outs[1], outs[2])


# ---------------------------------------------------------------------------
# 3. mid-epoch checkpoints
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fmt", ["dense", "hybrid"])
def test_mid_epoch_checkpoint_restores_bitwise(small_corpus, fmt, tmp_path):
    cfg = LDAConfig(n_topics=16, tile_size=512, format=fmt,
                    corpus_residency="streamed", stream_shards=4)
    tr = LDATrainer(small_corpus, cfg, _from_engine=True)
    pipe = tr.fused_pipeline()
    ss = pipe.from_lda_state(tr.init_state())
    ss, _, _ = pipe.run_fused(ss, 1)
    ref_ss = pipe.from_lda_state(tr.init_state())
    ref_ss, _, _ = pipe.run_fused(ref_ss, 3)           # uninterrupted
    ref = pipe.to_lda_state(ref_ss)

    # interrupt epoch 2 after 2 of 4 shards; round-trip through npz
    ss = pipe.run_shards(ss, 2)
    assert ss.cursor == 2
    payload = pipe.stream_payload(ss)
    assert int(payload["stream_cursor"]) == 2
    from repro.checkpoint import CheckpointManager
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(int(ss.iteration), payload)
    restored = mgr.restore_latest()

    tr2 = LDATrainer(small_corpus, cfg, _from_engine=True)
    p2 = tr2.fused_pipeline()
    s2 = p2.state_from_stream_payload(restored)
    assert s2.cursor == 2
    s2, _, _ = p2.run_fused(s2, 2)   # finish epoch 2 + epoch 3
    _assert_bitwise(small_corpus, ref, p2.to_lda_state(s2))


def test_boundary_payload_is_canonical(small_corpus):
    """Epoch-boundary stream payloads carry no stream_* keys, so they
    interchange with every other backend's canonical checkpoints."""
    cfg = LDAConfig(n_topics=16, tile_size=512,
                    corpus_residency="streamed", stream_shards=3)
    tr = LDATrainer(small_corpus, cfg, _from_engine=True)
    pipe = tr.fused_pipeline()
    ss, _, _ = pipe.run_fused(pipe.from_lda_state(tr.init_state()), 2)
    payload = pipe.stream_payload(ss)
    assert sorted(payload) == ["iteration", "key", "topics_global"]
    # and a resident trainer restores it (through the engine's padding)
    tr_r = LDATrainer(small_corpus, LDAConfig(n_topics=16, tile_size=512),
                      _from_engine=True)
    padded = np.zeros(tr_r.word_ids.shape, np.int32)
    padded[:small_corpus.n_tokens] = payload["topics_global"]
    state = tr_r.state_from_payload({"topics": padded,
                                     "key": payload["key"],
                                     "iteration": payload["iteration"]})
    _assert_bitwise(small_corpus, pipe.to_lda_state(ss), state)


def test_mid_epoch_payload_rejected_by_resident_trainer(small_corpus):
    tr = LDATrainer(small_corpus, LDAConfig(n_topics=16, tile_size=512),
                    _from_engine=True)
    with pytest.raises(ValueError, match="mid-epoch"):
        tr.state_from_payload({
            "topics": np.zeros(tr.word_ids.shape, np.int32),
            "key": np.zeros(2, np.uint32), "iteration": 1,
            "stream_cursor": 2,
            "stream_done_topics": np.zeros(8, np.int32)})


def test_mid_epoch_payload_rejected_by_distributed_engine(small_corpus):
    """The engine's distributed backend must NOT silently strip the
    stream_* keys: a mid-epoch restore there would re-sample the done
    shards and bit-diverge without an error."""
    from repro.lda.api import LDAEngine
    from repro.runtime.compat import make_mesh
    payload = {"topics_global": np.zeros(small_corpus.n_tokens, np.int32),
               "key": np.zeros(2, np.uint32), "iteration": 1,
               "stream_cursor": np.int64(2),
               "stream_done_topics": np.zeros(8, np.int32)}
    eng = LDAEngine(small_corpus, LDAConfig(n_topics=16, tile_size=512),
                    backend="distributed",
                    mesh=make_mesh((1, 1), ("data", "model")),
                    pad_multiple=256)
    with pytest.raises(ValueError, match="mid-epoch"):
        eng.restore(payload)


def test_to_lda_state_requires_epoch_boundary(small_corpus):
    cfg = LDAConfig(n_topics=16, tile_size=512,
                    corpus_residency="streamed", stream_shards=4)
    tr = LDATrainer(small_corpus, cfg, _from_engine=True)
    pipe = tr.fused_pipeline()
    ss = pipe.run_shards(pipe.from_lda_state(tr.init_state()), 1)
    with pytest.raises(ValueError, match="epoch boundary"):
        pipe.to_lda_state(ss)


# ---------------------------------------------------------------------------
# 4. the engine surface
# ---------------------------------------------------------------------------

def test_engine_streamed_matches_resident(small_corpus):
    """LDAEngine(corpus_residency='streamed') fits to the same canonical
    payload as the resident engine, and their checkpoints interchange."""
    from repro.lda.api import LDAEngine
    kw = dict(n_topics=16, tile_size=512, eval_every=5)
    eng_r = LDAEngine(small_corpus, LDAConfig(**kw), backend="single")
    eng_s = LDAEngine(small_corpus, LDAConfig(
        corpus_residency="streamed", stream_shards=4, **kw),
        backend="single")
    hist_r = eng_r.fit(6)
    hist_s = eng_s.fit(6)
    pay_r, pay_s = eng_r.host_payload(), eng_s.host_payload()
    assert np.array_equal(pay_r["topics_global"], pay_s["topics_global"])
    assert hist_r["iteration"] == hist_s["iteration"]
    # streamed engine restores the resident engine's checkpoint
    eng_s2 = LDAEngine(small_corpus, LDAConfig(
        corpus_residency="streamed", stream_shards=4, **kw),
        backend="single").restore(pay_r)
    assert eng_s2.iteration == eng_r.iteration
    eng_s2.fit(2)
    eng_r.fit(2)
    assert np.array_equal(eng_r.host_payload()["topics_global"],
                          eng_s2.host_payload()["topics_global"])


def test_engine_mid_epoch_save_restore(small_corpus, tmp_path):
    """engine.restore() accepts a mid-epoch payload and fit() continues
    it bit-identically (the first epoch finishes the open one)."""
    from repro.lda.api import LDAEngine
    kw = dict(n_topics=16, tile_size=512, corpus_residency="streamed",
              stream_shards=4)
    eng = LDAEngine(small_corpus, LDAConfig(**kw), backend="single")
    eng.fit(3)
    ref = eng.host_payload()

    eng2 = LDAEngine(small_corpus, LDAConfig(**kw), backend="single")
    eng2.fit(1)
    # advance 2 shards mid-epoch through the pipeline surface
    pipe = eng2.trainer.fused_pipeline()
    ss = pipe.from_lda_state(eng2.state)
    ss = pipe.run_shards(ss, 2)
    eng2._state = ss
    mid = eng2.host_payload()              # canonical + stream_* keys
    assert int(mid["stream_cursor"]) == 2

    eng3 = LDAEngine(small_corpus, LDAConfig(**kw),
                     backend="single").restore(mid)
    eng3.fit(2)                            # finish epoch 2 + epoch 3
    out = eng3.host_payload()
    assert np.array_equal(ref["topics_global"], out["topics_global"])
    assert sorted(out) == ["iteration", "key", "topics_global"]


def test_engine_auto_residency_by_budget(small_corpus):
    """'auto' streams iff 16B x padded tokens exceeds the budget."""
    from repro.lda.api import LDAEngine
    padded_n = -(-small_corpus.n_tokens // 512) * 512
    tokens_bytes = STREAM_BYTES_PER_TOKEN * padded_n
    eng_small = LDAEngine(small_corpus, LDAConfig(
        n_topics=16, tile_size=512, corpus_residency="auto",
        device_budget_bytes=tokens_bytes // 2), backend="single")
    assert eng_small.trainer.residency == "streamed"
    eng_big = LDAEngine(small_corpus, LDAConfig(
        n_topics=16, tile_size=512, corpus_residency="auto",
        device_budget_bytes=tokens_bytes * 10), backend="single")
    assert eng_big.trainer.residency == "full"


# ---------------------------------------------------------------------------
# 5. residency/shard planning + config validation
# ---------------------------------------------------------------------------

def test_plan_stream_shards_window_math():
    # 2 * 20B * N/S must fit budget/4: N=1e6, budget=64MB -> S = 4 (floor)
    assert plan_stream_shards(10 ** 6, 64 << 20) == 4
    # tight budget forces more shards: window = 1MB -> S = ceil(40e6/1e6)
    assert plan_stream_shards(10 ** 6, 4 << 20) == \
        -(-2 * 20 * 10 ** 6 // (1 << 20))
    # never more shards than pad multiples
    assert plan_stream_shards(4096, 1, multiple=1024) == 4
    assert plan_stream_shards(0, None) == 1


def test_resolve_residency_modes():
    cfg_full = LDAConfig(n_topics=8)
    assert resolve_residency(cfg_full, 10 ** 9) == ("full", 1)
    cfg_s = LDAConfig(n_topics=8, corpus_residency="streamed",
                      stream_shards=6)
    assert resolve_residency(cfg_s, 1000) == ("streamed", 6)
    # auto with no budget signal on CPU: stays resident
    cfg_auto = LDAConfig(n_topics=8, corpus_residency="auto")
    assert resolve_residency(cfg_auto, 10 ** 9)[0] in ("full", "streamed")


def test_config_rejects_bad_streaming_knobs():
    with pytest.raises(ValueError, match="corpus_residency"):
        LDAConfig(n_topics=8, corpus_residency="paged")
    with pytest.raises(ValueError, match="stream_shards"):
        LDAConfig(n_topics=8, stream_shards=1)
    with pytest.raises(ValueError, match="device_budget_bytes"):
        LDAConfig(n_topics=8, device_budget_bytes=0)


# ---------------------------------------------------------------------------
# 6. distributed streaming (single real device; forged meshes are slow)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fmt", ["dense", "hybrid"])
def test_streamed_equals_resident_distributed(small_corpus, fmt):
    from repro.lda.distributed import DistLDATrainer, DistStreamState
    from repro.runtime.compat import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    kw = dict(n_topics=16, tile_size=512, format=fmt)
    tr_r = DistLDATrainer(small_corpus, LDAConfig(**kw), mesh,
                          pad_multiple=256, _from_engine=True)
    s_r, _ = tr_r.run_fused(tr_r.init_state(), 4)
    tr_s = DistLDATrainer(small_corpus, LDAConfig(
        corpus_residency="streamed", stream_shards=3, **kw), mesh,
        pad_multiple=256, _from_engine=True)
    state = tr_s.init_state()
    assert isinstance(state, DistStreamState)
    with pytest.raises(ValueError, match="epochs"):
        tr_s.step(state)
    s_s, stats = tr_s.run_fused(state, 4)
    assert np.asarray(stats.frac_skipped).shape == (4,)
    pay_r, pay_s = tr_r.host_payload(s_r), tr_s.host_payload(s_s)
    assert np.array_equal(pay_r["topics_global"], pay_s["topics_global"])
    D_r, W_r = tr_r.gather_global(s_r)
    D_s, W_s = tr_s.gather_global(s_s)
    assert np.array_equal(D_r, D_s)
    assert np.array_equal(W_r, W_s)
    # checkpoints interchange: streamed payload restores resident & back
    # (pad-slot topics are inert derived state — compare real slots)
    sel = tr_r.sc.mask > 0
    s_r2 = tr_r.state_from_payload(pay_s)
    assert np.array_equal(np.asarray(s_r2.topics)[sel],
                          np.asarray(s_r.topics)[sel])
    s_s2 = tr_s.state_from_payload(pay_r)
    n_loc = tr_s.stream.n_loc
    assert np.array_equal(s_s2.host_topics[:, :n_loc][sel],
                          s_s.host_topics[:, :n_loc][sel])


@pytest.mark.slow
def test_streamed_distributed_forged_devices():
    """Streamed == resident over a real multi-device mesh (8 forged CPU
    devices), including balance='tiles' dissection and model parallelism."""
    import subprocess, sys, textwrap
    code = """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, "src")
    import numpy as np, jax
    from repro.lda.corpus import synthetic_lda_corpus, relabel_by_frequency
    from repro.lda.model import LDAConfig
    from repro.lda.distributed import DistLDATrainer
    corpus = synthetic_lda_corpus(0, n_docs=80, n_words=100, n_topics=8,
                                  mean_doc_len=50)
    corpus, _ = relabel_by_frequency(corpus)
    for shape, fmt, bal in (((4, 2), "dense", "none"),
                            ((4, 1), "dense", "tiles"),
                            ((8, 1), "hybrid", "none")):
        mesh = jax.make_mesh(shape, ("data", "model"))
        kw = dict(n_topics=16, tile_size=512, format=fmt, balance=bal)
        tr_r = DistLDATrainer(corpus, LDAConfig(**kw), mesh,
                              pad_multiple=256, _from_engine=True)
        s_r, _ = tr_r.run_fused(tr_r.init_state(), 4)
        tr_s = DistLDATrainer(corpus, LDAConfig(
            corpus_residency="streamed", stream_shards=3, **kw), mesh,
            pad_multiple=256, _from_engine=True)
        s_s, _ = tr_s.run_fused(tr_s.init_state(), 4)
        assert np.array_equal(tr_r.host_payload(s_r)["topics_global"],
                              tr_s.host_payload(s_s)["topics_global"]), \\
            (shape, fmt, bal)
        D_r, W_r = tr_r.gather_global(s_r)
        D_s, W_s = tr_s.gather_global(s_s)
        assert np.array_equal(D_r, D_s) and np.array_equal(W_r, W_s)
    print("OK")
    """
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=900, cwd=".")
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "OK" in proc.stdout


# ---------------------------------------------------------------------------
# 7. measured memory accounting
# ---------------------------------------------------------------------------

# ---------------------------------------------------------------------------
# 8. disk-native training (corpus_residency="disk", DESIGN.md SS14)
# ---------------------------------------------------------------------------

def _disk_store(corpus, tmp_path, n_shards=4, multiple=512):
    return shard_stream(corpus, n_shards, multiple=multiple).to_store(
        str(tmp_path / "store"))


def _disk_engine(store, tmp_path=None, **kw):
    from repro.lda.api import LDAEngine
    cfg = LDAConfig(corpus_residency="disk", corpus_path=store.path, **kw)
    ck = {} if tmp_path is None else \
        {"checkpoint_dir": str(tmp_path / "ck")}
    return LDAEngine(None, cfg, backend="single", **ck)


@pytest.mark.parametrize("fmt,extra", [
    ("dense", {}),
    ("hybrid", {}),
    ("hybrid", {"tail_sampler": "sparse"}),
    ("dense", {"balance": "tiles"}),
    ("dense", {"impl": "pallas"}),
])
def test_disk_equals_streamed_equals_resident(small_corpus, fmt, extra,
                                              tmp_path):
    """The full residency ladder is bitwise ONE training run: resident ==
    streamed == disk-native (corpus read from shard files, W paged per
    shard) on topics, key, and the exact LLPT history."""
    from repro.lda.api import LDAEngine
    kw = dict(n_topics=16, tile_size=512, eval_every=2, format=fmt, **extra)
    eng_r = LDAEngine(small_corpus, LDAConfig(**kw), backend="single")
    hist_r = eng_r.fit(4)
    store = _disk_store(eng_r.corpus, tmp_path)
    eng_s = LDAEngine(small_corpus, LDAConfig(
        corpus_residency="streamed", stream_shards=4, **kw),
        backend="single")
    hist_s = eng_s.fit(4)
    eng_d = _disk_engine(store, **kw)
    assert eng_d.trainer.residency == "disk"
    assert eng_d.trainer.fused_pipeline().paged
    hist_d = eng_d.fit(4)
    pay_r, pay_s, pay_d = (eng_r.host_payload(), eng_s.host_payload(),
                           eng_d.host_payload())
    assert np.array_equal(pay_r["topics_global"], pay_s["topics_global"])
    assert np.array_equal(pay_r["topics_global"], pay_d["topics_global"])
    assert np.array_equal(pay_r["key"], pay_d["key"])
    assert hist_r["llpt"] == hist_s["llpt"] == hist_d["llpt"]


def test_disk_eval_equals_resident_eval_exactly(small_corpus, tmp_path):
    """Paged shard-fold LLPT == resident LLPT bitwise: per-token values
    through the one same compiled reduce (core/llpt.py split)."""
    from repro.lda.api import LDAEngine
    kw = dict(n_topics=16, tile_size=512, eval_every=5)
    eng_r = LDAEngine(small_corpus, LDAConfig(**kw), backend="single")
    eng_r.fit(3)
    store = _disk_store(eng_r.corpus, tmp_path)
    eng_d = _disk_engine(store, **kw)
    eng_d.fit(3)
    assert eng_r.score() == eng_d.score()


def test_disk_checkpoints_interchange_with_resident(small_corpus, tmp_path):
    """A disk engine restores a resident engine's canonical checkpoint
    and continues bitwise, and vice versa."""
    from repro.lda.api import LDAEngine
    kw = dict(n_topics=16, tile_size=512, eval_every=5)
    eng_r = LDAEngine(small_corpus, LDAConfig(**kw), backend="single")
    eng_r.fit(2)
    store = _disk_store(eng_r.corpus, tmp_path)
    eng_d = _disk_engine(store, **kw).restore(eng_r.host_payload())
    eng_d.fit(2)
    eng_r.fit(2)
    pay_r, pay_d = eng_r.host_payload(), eng_d.host_payload()
    assert np.array_equal(pay_r["topics_global"], pay_d["topics_global"])
    # and back: the resident engine restores the disk engine's payload
    eng_r2 = LDAEngine(small_corpus, LDAConfig(**kw),
                       backend="single").restore(pay_d)
    assert eng_r2.iteration == eng_d.iteration
    assert eng_r2.score() == eng_d.score()


def test_disk_mid_epoch_checkpoint_resumes_bitwise(small_corpus, tmp_path):
    """A mid-epoch disk payload (manifest-relative stream cursor) restores
    into a FRESH engine and finishes bit-identically."""
    from repro.lda.api import LDAEngine
    kw = dict(n_topics=16, tile_size=512, eval_every=5)
    eng_r = LDAEngine(small_corpus, LDAConfig(**kw), backend="single")
    eng_r.fit(3)
    ref = eng_r.host_payload()
    store = _disk_store(eng_r.corpus, tmp_path)

    eng_d = _disk_engine(store, **kw)
    eng_d.fit(1)
    pipe = eng_d.trainer.fused_pipeline()
    ss = pipe.run_shards(pipe.from_lda_state(eng_d.state), 2)
    eng_d._state = ss
    mid = eng_d.host_payload()
    assert int(mid["stream_cursor"]) == 2
    assert int(mid["stream_n_shards"]) == store.n_shards

    eng_d2 = _disk_engine(store, **kw).restore(mid)
    eng_d2.fit(2)               # finish epoch 2 + epoch 3
    assert np.array_equal(ref["topics_global"],
                          eng_d2.host_payload()["topics_global"])


def test_mid_epoch_payload_rejects_shard_grid_mismatch(small_corpus,
                                                      tmp_path):
    """A mid-epoch cursor is only meaningful on the shard grid it was
    saved against: restoring it into a store with a different n_shards
    must fail loudly, not resample the wrong shards."""
    from repro.lda.api import LDAEngine
    kw = dict(n_topics=16, tile_size=512, eval_every=5)
    eng_r = LDAEngine(small_corpus, LDAConfig(**kw), backend="single")
    eng_r.fit(1)
    store4 = _disk_store(eng_r.corpus, tmp_path)
    eng_d = _disk_engine(store4, **kw)
    eng_d.fit(1)
    pipe = eng_d.trainer.fused_pipeline()
    ss = pipe.run_shards(pipe.from_lda_state(eng_d.state), 2)
    eng_d._state = ss
    mid = eng_d.host_payload()
    store2 = shard_stream(eng_r.corpus, 2, multiple=512).to_store(
        str(tmp_path / "store2"))
    eng_d2 = _disk_engine(store2, **kw)
    with pytest.raises(ValueError, match="shard grid"):
        eng_d2.restore(mid)


def test_disk_config_validation(tmp_path):
    with pytest.raises(ValueError, match="corpus_path"):
        LDAConfig(n_topics=8, corpus_residency="disk")
    with pytest.raises(ValueError, match="corpus_path"):
        LDAConfig(n_topics=8, corpus_path="/somewhere")
    with pytest.raises(ValueError, match="stream_shards"):
        LDAConfig(n_topics=8, corpus_residency="disk",
                  corpus_path="/somewhere", stream_shards=4)


def test_disk_engine_guards(small_corpus, tmp_path):
    from repro.lda.api import LDAEngine
    store = _disk_store(small_corpus, tmp_path)
    cfg = LDAConfig(n_topics=16, tile_size=512, corpus_residency="disk",
                    corpus_path=store.path)
    # a resident corpus alongside a disk config would silently diverge
    with pytest.raises(ValueError, match="corpus=None"):
        LDAEngine(small_corpus, cfg, backend="single")
    # no corpus and no store path is no corpus at all
    with pytest.raises(ValueError, match="disk"):
        LDAEngine(None, LDAConfig(n_topics=16), backend="single")
    # disk is single-backend: the paged pipeline owns the device schedule
    with pytest.raises(ValueError, match="single"):
        LDAEngine(None, cfg, backend="distributed")
    # the stepwise oracle path needs resident tokens
    eng = LDAEngine(None, cfg, backend="single")
    eng.fit(1)
    with pytest.raises(ValueError, match="resident"):
        eng.trainer.step(eng.state)


def test_disk_pages_w_per_shard(small_corpus, tmp_path):
    """The paged pipeline's device window holds a W ROW BLOCK, not the
    full matrix: page_rows is the max word-run span, and the epoch's
    device-byte accounting reflects the paged window."""
    store = _disk_store(small_corpus, tmp_path, n_shards=8)
    cfg = LDAConfig(n_topics=16, tile_size=512, corpus_residency="disk",
                    corpus_path=store.path)
    tr = LDATrainer(None, cfg, _from_engine=True)
    pipe = tr.fused_pipeline()
    assert pipe.paged
    spans = np.maximum(
        store.last_word.astype(np.int64) - store.first_word + 1, 1)
    assert pipe._page_rows == min(max(int(spans.max()), 1), store.n_words)
    assert pipe._page_rows < store.n_words      # a real window, not all of W
    ss, _, _ = pipe.run_fused(pipe.from_lda_state(tr.init_state()), 1)
    assert pipe.last_epoch_device_bytes > 0
    # serving view at the boundary is the exact at-rest W
    W, cursor, n_sh = pipe.serving_counts(ss)
    assert cursor == 0 and n_sh == store.n_shards
    assert np.array_equal(W, np.asarray(pipe.to_lda_state(ss).W))


@pytest.mark.slow
def test_disk_equals_resident_forged_devices(tmp_path):
    """disk == resident bitwise with 8 forged CPU devices visible: the
    single-backend paged pipeline must not be perturbed by a multi-device
    runtime (and engine backend='auto' must route disk to single)."""
    import subprocess, sys, textwrap
    code = f"""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, "src")
    import numpy as np, jax
    from repro.lda.corpus import (synthetic_lda_corpus, relabel_by_frequency,
                                  shard_stream)
    from repro.lda.model import LDAConfig
    from repro.lda.api import LDAEngine
    corpus = synthetic_lda_corpus(0, n_docs=80, n_words=100, n_topics=8,
                                  mean_doc_len=50)
    corpus, _ = relabel_by_frequency(corpus)
    store = shard_stream(corpus, 3, multiple=512).to_store(
        {str(tmp_path / "store8")!r})
    for fmt in ("dense", "hybrid"):
        kw = dict(n_topics=16, tile_size=512, eval_every=5, format=fmt)
        eng_r = LDAEngine(corpus, LDAConfig(**kw), backend="single")
        eng_r.fit(4)
        eng_d = LDAEngine(None, LDAConfig(
            corpus_residency="disk", corpus_path=store.path, **kw))
        assert eng_d.backend_name == "single"       # auto routes to single
        eng_d.fit(4)
        pr, pd = eng_r.host_payload(), eng_d.host_payload()
        assert np.array_equal(pr["topics_global"], pd["topics_global"]), fmt
        assert np.array_equal(pr["key"], pd["key"]), fmt
        assert eng_r.score() == eng_d.score(), fmt
    print("OK")
    """
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=900, cwd=".")
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "OK" in proc.stdout


def test_streamed_device_bytes_below_resident(small_corpus):
    """The streaming window accounting: resident token+state bytes vs
    the streamed steady state (counts + epoch arrays + two shard
    windows). On this small corpus the absolute win is modest; the
    benchmark (fig19) pins the <= 0.6x bar on a token-dominated corpus —
    here we only require the token-side win to be real."""
    cfg = LDAConfig(n_topics=16, tile_size=512,
                    corpus_residency="streamed", stream_shards=8)
    tr = LDATrainer(small_corpus, cfg, _from_engine=True)
    pipe = tr.fused_pipeline()
    ss, _, _ = pipe.run_fused(pipe.from_lda_state(tr.init_state()), 1)
    assert pipe.last_epoch_device_bytes > 0
    resident_token_bytes = pipe.stream.token_bytes_resident()
    streamed_token_bytes = pipe.stream.token_bytes_streamed()
    assert streamed_token_bytes < resident_token_bytes
    assert streamed_token_bytes == 2 * 20 * pipe.stream.shard_len
