"""Tests for the paper's core contribution: three-branch sampling (Eq 6-10).

The load-bearing properties:
  1. Eq 9/10: S' <= S_est for any counts (hypothesis property test).
  2. The skip theorem: a skipped token's exact sample is K1 (never changes
     the distribution).
  3. Three-branch sampling induces exactly p ∝ (D[d]+α)∘Ŵ[v] (stratified-u
     total-variation check) — same distribution as two-branch.
  4. The compacted (capacity) path is bit-identical to the reference path.
  5. End-to-end: LLPT rises; skip fraction grows over iterations (Fig 12b)
     and with g (paper parameter study).
"""

import jax
import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st

from repro.core import esca, three_branch
from repro.lda.model import LDAConfig
from repro.lda.trainer import LDATrainer

jax.config.update("jax_platform_name", "cpu")


def _random_state(seed, n_docs=30, n_words=40, K=12, n=800):
    rng = np.random.default_rng(seed)
    word_ids = np.sort(rng.integers(0, n_words, n)).astype(np.int32)
    doc_ids = rng.integers(0, n_docs, n).astype(np.int32)
    topics = rng.integers(0, K, n).astype(np.int32)
    D = np.zeros((n_docs, K), np.int32)
    W = np.zeros((n_words, K), np.int32)
    np.add.at(D, (doc_ids, topics), 1)
    np.add.at(W, (word_ids, topics), 1)
    return (jnp.asarray(word_ids), jnp.asarray(doc_ids), jnp.asarray(topics),
            jnp.asarray(D), jnp.asarray(W))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), g=st.integers(1, 4))
def test_s_est_upper_bounds_s_prime(seed, g):
    """Eq 9/10: the g-term tail estimate dominates the true S'."""
    word_ids, doc_ids, _, D, W = _random_state(seed)
    alpha, beta = 50.0 / 12, 0.01
    W_hat = esca.compute_w_hat(W, beta)
    sw = three_branch.word_stats(W_hat, g=g, alpha=alpha)
    u = jnp.zeros(word_ids.shape[0], jnp.float32)
    dec = three_branch.skip_phase(u, word_ids, doc_ids, D, sw, g=g, alpha=alpha)
    # true S' = sum_k D[d][k]*W_hat[v][k] − a1*b1
    Wv = np.asarray(W_hat)[np.asarray(word_ids)]
    Dd = np.asarray(D, np.float32)[np.asarray(doc_ids)]
    k1 = np.asarray(sw.k[:, 0])[np.asarray(word_ids)]
    a1 = np.asarray(sw.a[:, 0])[np.asarray(word_ids)]
    b1 = Dd[np.arange(len(k1)), k1]
    s_true = (Wv * Dd).sum(-1) - a1 * b1
    assert np.all(np.asarray(dec.s_est) >= s_true - 1e-4), \
        (np.asarray(dec.s_est) - s_true).min()


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_skip_theorem(seed):
    """Skipped tokens would have sampled K1 under the exact sampler."""
    word_ids, doc_ids, _, D, W = _random_state(seed)
    alpha, beta = 50.0 / 12, 0.01
    W_hat = esca.compute_w_hat(W, beta)
    sw = three_branch.word_stats(W_hat, g=2, alpha=alpha)
    u = jax.random.uniform(jax.random.PRNGKey(seed), word_ids.shape,
                           dtype=jnp.float32)
    dec = three_branch.skip_phase(u, word_ids, doc_ids, D, sw, g=2, alpha=alpha)
    topics_exact, _ = three_branch.exact_three_branch(
        u, word_ids, doc_ids, sw.k[:, 0], D, W_hat, alpha=alpha, tile_size=256)
    viol = np.asarray(dec.skip & (topics_exact != dec.k1))
    assert viol.sum() == 0


def test_three_branch_distribution_matches_exact_p():
    """Stratified-u sweep: induced topic histogram == p ∝ (D+α)∘Ŵ."""
    word_ids, doc_ids, _, D, W = _random_state(7)
    K = 12
    alpha, beta = 50.0 / K, 0.01
    W_hat = esca.compute_w_hat(W, beta)
    sw = three_branch.word_stats(W_hat, g=2, alpha=alpha)
    for tok in (0, 100, 500):
        v, d = int(word_ids[tok]), int(doc_ids[tok])
        p = (np.asarray(D[d]) + alpha) * np.asarray(W_hat[v])
        p = p / p.sum()
        n = 100_000
        us = jnp.asarray((np.arange(n) + 0.5) / n, jnp.float32)
        t3, _ = three_branch.exact_three_branch(
            us, jnp.full(n, v, jnp.int32), jnp.full(n, d, jnp.int32),
            sw.k[:, 0], D, W_hat, alpha=alpha, tile_size=8192)
        h = np.bincount(np.asarray(t3), minlength=K) / n
        assert 0.5 * np.abs(h - p).sum() < 1e-3


def test_three_branch_matches_two_branch_distribution():
    """Both samplers induce the same distribution (different u→topic maps)."""
    word_ids, doc_ids, topics, D, W = _random_state(11)
    K = 12
    alpha, beta = 50.0 / K, 0.01
    W_hat = esca.compute_w_hat(W, beta)
    v, d = int(word_ids[50]), int(doc_ids[50])
    n = 100_000
    us = jnp.asarray((np.arange(n) + 0.5) / n, jnp.float32)
    vv = jnp.full(n, v, jnp.int32)
    dd = jnp.full(n, d, jnp.int32)
    t2, _ = esca.sample_two_branch(jax.random.PRNGKey(0), vv, dd,
                                   jnp.zeros(n, jnp.int32), D, W_hat,
                                   alpha=alpha, tile_size=8192)
    # two-branch uses its own key; rebuild with stratified u via internals
    from repro.core.esca import _sample_token
    t2 = jax.vmap(lambda u: _sample_token(u, D[d], W_hat[v],
                                          jnp.float32(alpha))[0])(us)
    sw = three_branch.word_stats(W_hat, g=2, alpha=alpha)
    t3, _ = three_branch.exact_three_branch(us, vv, dd, sw.k[:, 0], D, W_hat,
                                            alpha=alpha, tile_size=8192)
    h2 = np.bincount(np.asarray(t2), minlength=K) / n
    h3 = np.bincount(np.asarray(t3), minlength=K) / n
    assert 0.5 * np.abs(h2 - h3).sum() < 1e-3


def test_compacted_path_equals_reference(small_corpus, small_config):
    cfg = small_config
    tr = LDATrainer(small_corpus, cfg, _from_engine=True)
    state = tr.init_state()
    for _ in range(3):
        state, _ = tr.step(state)
    key = jax.random.PRNGKey(9)
    for cap in (64, 777, 100_000):
        plan_ref = three_branch.Plan(g=2, tile_size=512, capacity=None)
        plan_cap = three_branch.Plan(g=2, tile_size=512, capacity=cap)
        t_ref, s_ref = three_branch.sample(
            key, plan_ref, tr.word_ids, tr.doc_ids, state.topics,
            state.D, state.W, cfg)
        t_cap, s_cap = three_branch.sample(
            key, plan_cap, tr.word_ids, tr.doc_ids, state.topics,
            state.D, state.W, cfg)
        assert bool(jnp.all(t_ref == t_cap))
        assert float(s_ref.frac_skipped) == float(s_cap.frac_skipped)


def test_llpt_rises_and_skip_grows(small_corpus):
    """End-to-end: LLPT increases; skip fraction grows as tokens converge
    (paper Figs 3 & 12b)."""
    cfg = LDAConfig(n_topics=16, tile_size=512, eval_every=5)
    tr = LDATrainer(small_corpus, cfg, _from_engine=True)
    state = tr.init_state()
    llpt0 = tr.evaluate(state)
    skips = []
    for i in range(20):
        state, stats = tr.step(state)
        skips.append(float(stats["frac_skipped"]))
    llpt1 = tr.evaluate(state)
    assert llpt1 > llpt0 + 0.05, (llpt0, llpt1)
    assert np.mean(skips[-5:]) > np.mean(skips[:5]), skips
    assert not np.isnan(llpt1)


def test_skip_fraction_increases_with_g(small_corpus):
    """Paper §III-B: larger g ⇒ tighter S_est ⇒ more skips."""
    cfg = LDAConfig(n_topics=16, tile_size=512)
    tr = LDATrainer(small_corpus, cfg, _from_engine=True)
    state = tr.init_state()
    for _ in range(10):
        state, _ = tr.step(state)
    key = jax.random.PRNGKey(3)
    fracs = {}
    for g in (1, 2, 4):
        plan = three_branch.Plan(g=g, tile_size=512, capacity=None)
        _, st = three_branch.sample(key, plan, tr.word_ids, tr.doc_ids,
                                    state.topics, state.D, state.W, cfg)
        fracs[g] = float(st.frac_skipped)
    assert fracs[1] <= fracs[2] + 1e-6 and fracs[2] <= fracs[4] + 1e-6, fracs


def test_two_and_three_branch_converge_to_same_llpt(small_corpus):
    """The samplers share one stationary distribution: final LLPT agrees."""
    res = {}
    for sampler in ("two_branch", "three_branch"):
        cfg = LDAConfig(n_topics=16, tile_size=512, sampler=sampler, seed=4)
        tr = LDATrainer(small_corpus, cfg, _from_engine=True)
        state = tr.init_state()
        for _ in range(25):
            state, _ = tr.step(state)
        res[sampler] = tr.evaluate(state)
    assert abs(res["two_branch"] - res["three_branch"]) < 0.15, res
