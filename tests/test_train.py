"""Training substrate tests: partition rules, AdamW/ZeRO-1, train_step
convergence, serve_step decode loop, data determinism."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import REGISTRY
from repro.data.synthetic import SyntheticLM, make_batch
from repro.models.registry import get_model, reduced_config
from repro.train import partition
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state, lr_at
from repro.train.train_step import make_train_step
from repro.train.serve_step import make_serve_step


def _mesh11():
    from repro.runtime.compat import make_mesh
    return make_mesh((1, 1), ("data", "model"))


def test_partition_rules_cover_all_archs():
    """Every param gets a spec; rules never assign a non-dividing axis."""
    mesh = _mesh11()
    for arch, cfg0 in REGISTRY.items():
        cfg = reduced_config(cfg0)
        api = get_model(cfg)
        shapes = jax.eval_shape(api.init, jax.random.PRNGKey(0))
        specs = partition.param_specs(mesh, shapes)
        flat_specs = jax.tree.leaves(specs,
                                     is_leaf=lambda x: isinstance(x, P))
        flat_shapes = jax.tree.leaves(shapes)
        assert len(flat_specs) == len(flat_shapes), arch


def test_partition_rules_shard_big_tensors():
    """On a 16-way model mesh, the big matmul weights must actually shard
    (this is what makes 33B fit; replication here is a memory bug)."""
    import os
    import subprocess
    import sys
    import textwrap
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
        import sys; sys.path.insert(0, "src")
        import jax
        from jax.sharding import PartitionSpec as P
        from repro.configs import REGISTRY
        from repro.models.registry import get_model
        from repro.train import partition
        mesh = jax.make_mesh((1, 16), ("data", "model"))
        cfg = REGISTRY["deepseek-coder-33b"]
        api = get_model(cfg)
        shapes = jax.eval_shape(api.init, jax.random.PRNGKey(0))
        specs = partition.param_specs(mesh, shapes)
        flat = jax.tree_util.tree_leaves_with_path(shapes)
        sp = {partition._path_str(p): s for (p, _), s in
              zip(flat, jax.tree.leaves(specs,
                  is_leaf=lambda x: isinstance(x, P)))}
        assert sp["embed/table"][0] == "model", sp["embed/table"]
        assert sp["blocks/attn/wq/w"][2] == "model"
        assert sp["blocks/mlp/w_gate"][2] == "model"
        assert sp["blocks/mlp/w_down"][1] == "model"
        print("OK")
    """)
    proc = subprocess.run([sys.executable, "-c", code],
                          capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0 and "OK" in proc.stdout, proc.stderr[-2000:]


def test_zero1_adds_data_sharding():
    import subprocess
    import sys
    import textwrap
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, "src")
        import jax
        from jax.sharding import PartitionSpec as P
        from repro.configs import REGISTRY
        from repro.models.registry import get_model
        from repro.train import partition
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cfg = REGISTRY["qwen1.5-0.5b"]
        api = get_model(cfg)
        shapes = jax.eval_shape(api.init, jax.random.PRNGKey(0))
        z = partition.zero1_specs(mesh, shapes)
        flat = jax.tree.leaves(z, is_leaf=lambda x: isinstance(x, P))
        n_data_sharded = sum(
            any(e == "data" or (isinstance(e, tuple) and "data" in e)
                for e in s) for s in flat)
        assert n_data_sharded > len(flat) * 0.5, n_data_sharded
        print("OK")
    """)
    proc = subprocess.run([sys.executable, "-c", code],
                          capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0 and "OK" in proc.stdout, proc.stderr[-2000:]


def test_adamw_descends_quadratic():
    cfg = AdamWConfig(lr=0.1, warmup_steps=1, total_steps=200,
                      weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    opt = init_opt_state(params)
    for _ in range(100):
        grads = {"w": params["w"]}          # ∇ of ||w||²/2
        params, opt, metrics = adamw_update(cfg, grads, opt,
                                            param_dtype=jnp.float32)
    assert float(jnp.abs(params["w"]).max()) < 0.2
    assert np.isfinite(float(metrics["grad_norm"]))


def test_lr_schedule_shape():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(lr_at(cfg, jnp.int32(s))) for s in (0, 5, 10, 50, 100)]
    assert lrs[0] < lrs[1] < lrs[2]                 # warmup rises
    assert lrs[2] >= lrs[3] >= lrs[4]               # cosine decays
    assert abs(lrs[2] - 1e-3) < 1e-9


def test_train_step_loss_decreases():
    mesh = _mesh11()
    cfg = reduced_config(REGISTRY["qwen1.5-0.5b"], n_layers=2, d_model=64)
    api = get_model(cfg)
    opt = AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=100)
    step, init_state = make_train_step(api, mesh, n_micro=2, opt_cfg=opt)
    state = init_state(jax.random.PRNGKey(0))
    jstep = jax.jit(step, donate_argnums=(0,))
    losses = []
    for i in range(40):
        batch = {k: jnp.asarray(v) for k, v in
                 make_batch(cfg, 64, 8, "train", step=i).items()}
        state, metrics = jstep(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.4 and np.isfinite(losses).all()


def test_microbatching_is_loss_equivalent():
    """n_micro=1 and n_micro=4 must give (nearly) the same step-0 loss and
    gradient direction — accumulation correctness."""
    mesh = _mesh11()
    cfg = reduced_config(REGISTRY["qwen1.5-0.5b"], n_layers=2, d_model=64)
    cfg = dataclasses.replace(cfg, param_dtype="float32")
    api = get_model(cfg)
    batch = {k: jnp.asarray(v) for k, v in
             make_batch(cfg, 32, 8, "train", step=0).items()}
    outs = {}
    for n_micro in (1, 4):
        step, init_state = make_train_step(api, mesh, n_micro=n_micro)
        state = init_state(jax.random.PRNGKey(0))
        new_state, metrics = jax.jit(step)(state, batch)
        outs[n_micro] = (float(metrics["loss"]),
                         float(metrics["grad_norm"]))
    assert abs(outs[1][0] - outs[4][0]) < 1e-3, outs
    assert abs(outs[1][1] - outs[4][1]) / outs[1][1] < 2e-2, outs


def test_serve_step_greedy_decode_runs():
    mesh = _mesh11()
    cfg = reduced_config(REGISTRY["qwen1.5-0.5b"], n_layers=2, d_model=64,
                         vocab_size=128, vocab_pad_multiple=64)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    serve = jax.jit(make_serve_step(api, mesh), donate_argnums=(1,))
    cache = api.make_cache(4, 16)
    toks = jnp.zeros((4, 1), jnp.int32)
    for _ in range(8):
        logits, cache = serve(params, cache, toks)
        toks = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    assert int(cache["length"]) == 8
    assert np.isfinite(np.asarray(logits[:, :cfg.vocab_size])).all()


def test_synthetic_data_deterministic():
    g = SyntheticLM(1000, seed=7)
    a = g.batch(3, 4, 16)
    b = g.batch(3, 4, 16)
    assert np.array_equal(a["inputs"], b["inputs"])
    c = g.batch(4, 4, 16)
    assert not np.array_equal(a["inputs"], c["inputs"])
    # labels are inputs shifted by one
    full_a = np.concatenate([a["inputs"], a["labels"][:, -1:]], axis=1)
    assert np.array_equal(full_a[:, 1:], a["labels"])
