"""``sampler="warp"`` tests (paper §III fast-sampler context, DESIGN.md SS12).

The load-bearing properties:
  1. Alias tables are *valid* (reconstruction: prob/K + redirected mass
     == q row for row) and *deterministic* (hypothesis-driven: the same
     counts build bitwise-identical tables across two builds, and
     ``word_stats`` is likewise build-stable — satellite for the shared
     snapshot machinery). Row independence: tables of a sliced window
     equal the slice of global tables, which is what lets the Pallas
     kernel build per-tile tables that match the global build.
  2. The f32 MH chain matches a float64 NumPy oracle: per-proposal
     acceptance ratios agree to f32 tolerance and final topics agree
     exactly away from predicate boundaries.
  3. Stationarity: warp and the exact three-branch sampler converge to
     statistically indistinguishable held-in LLPT plateaus.
  4. Path equivalences, all bitwise: fused(1-iter scans) == stepwise;
     pallas == xla (window engaged and cond-fallback); hybrid == dense.
  5. Config surface: unknown sampler/impl/balance name the valid
     options; mh_cycles >= 1; streamed + distributed reject warp with
     actionable errors.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from _hyp import given, settings, st
from repro.core import mh, three_branch
from repro.lda import invariants
from repro.lda.corpus import relabel_by_frequency, zipf_corpus
from repro.lda.model import LDAConfig
from repro.lda.trainer import LDATrainer

jax.config.update("jax_platform_name", "cpu")

BASE = dict(n_topics=16, tile_size=512, sampler="warp", seed=1)


def _rand_weights(rng, V, K):
    # count-shaped weights with the spiky rows real W_hat rows have
    w = rng.integers(0, 50, (V, K)).astype(np.float32)
    w[rng.random((V, K)) < 0.6] = 0.0
    return w + 0.1


# ---------------------------------------------------------------------------
# 1. alias tables: validity, determinism, row independence
# ---------------------------------------------------------------------------

def test_alias_tables_reconstruct():
    rng = np.random.default_rng(0)
    tables = mh.build_alias_tables(jnp.asarray(_rand_weights(rng, 40, 16)))
    invariants.check_alias_tables(tables.prob, tables.alias, tables.q,
                                  where="test reconstruction")


def test_check_alias_tables_rejects_corruption():
    rng = np.random.default_rng(1)
    tables = mh.build_alias_tables(jnp.asarray(_rand_weights(rng, 10, 8)))
    prob = np.asarray(tables.prob).copy()
    alias = np.asarray(tables.alias).copy()
    q = np.asarray(tables.q)
    with pytest.raises(invariants.InvariantViolation):
        bad = prob.copy(); bad[3, 2] = 2.0
        invariants.check_alias_tables(bad, alias, q, where="t")
    with pytest.raises(invariants.InvariantViolation):
        bad = alias.copy(); bad[0, 0] = 99
        invariants.check_alias_tables(prob, bad, q, where="t")
    with pytest.raises(invariants.InvariantViolation):
        bad = q.copy(); bad[5] = np.roll(bad[5], 1)
        invariants.check_alias_tables(prob, alias, bad, where="t")


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_alias_and_word_stats_build_determinism(seed):
    """Same key counts ⇒ bitwise-identical proposal snapshots, twice over.

    Both scan-start snapshot builds — ``word_stats`` for the exact
    sampler, the alias tables for warp — must be pure functions of the
    counts, or the resume/replay machinery (PR 6) and the pallas/xla
    equivalences below stop being bitwise statements.
    """
    rng = np.random.default_rng(seed)
    V = int(rng.integers(4, 40))
    K = int(rng.integers(2, 24))
    w = _rand_weights(rng, V, K)
    t1 = mh.build_alias_tables(jnp.asarray(w.copy()))
    t2 = mh.build_alias_tables(jnp.asarray(w.copy()))
    for a, b in zip(t1, t2):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    s1 = three_branch.word_stats(jnp.asarray(w.copy()), g=2, alpha=0.1)
    s2 = three_branch.word_stats(jnp.asarray(w.copy()), g=2, alpha=0.1)
    for a, b in zip(s1, s2):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    invariants.check_alias_tables(t1.prob, t1.alias, t1.q, where="hyp")


def test_alias_window_equals_global_slice():
    """Row independence — the property the tile-local kernel build rests
    on: tables built from a window of rows == slice of global tables."""
    rng = np.random.default_rng(3)
    w = _rand_weights(rng, 50, 16)
    full = mh.build_alias_tables(jnp.asarray(w))
    win = mh.build_alias_tables(jnp.asarray(w[17:33]))
    assert np.array_equal(np.asarray(full.prob)[17:33], np.asarray(win.prob))
    assert np.array_equal(np.asarray(full.alias)[17:33], np.asarray(win.alias))


def test_onehot_vose_bit_equal_scatter():
    """The Pallas kernel runs the one-hot Vose variant; it must produce
    the same bits as the scatter variant the host build uses."""
    rng = np.random.default_rng(4)
    w = jnp.asarray(_rand_weights(rng, 30, 12))
    q = w / jnp.sum(w, axis=1, keepdims=True)
    scaled = q * w.shape[1]
    squeue, lqueue, n_small = mh.alias_queues(scaled)
    p1, a1 = mh.run_vose(scaled, squeue, lqueue, n_small)
    p2, a2 = mh.run_vose(scaled, squeue, lqueue, n_small, onehot=True)
    assert np.array_equal(np.asarray(p1), np.asarray(p2))
    assert np.array_equal(np.asarray(a1), np.asarray(a2))


# ---------------------------------------------------------------------------
# 2. float64 acceptance-ratio oracle
# ---------------------------------------------------------------------------

def test_mh_chain_matches_float64_oracle():
    rng = np.random.default_rng(7)
    V, K, M, n, C = 30, 12, 20, 600, 3
    D = rng.integers(0, 40, (M, K)).astype(np.int32)
    W_hat = _rand_weights(rng, V, K)
    tables = mh.build_alias_tables(jnp.asarray(W_hat))
    d_ids = rng.integers(0, M, n).astype(np.int32)
    w_ids = rng.integers(0, V, n).astype(np.int32)
    s0 = rng.integers(0, K, n).astype(np.int32)
    t_doc = rng.integers(0, K, (C, n)).astype(np.int32)
    t_word = rng.integers(0, K, (C, n)).astype(np.int32)
    u_acc = rng.random((C, 2, n)).astype(np.float32)

    Dj, Wj = jnp.asarray(D), jnp.asarray(W_hat)
    dj, wj = jnp.asarray(d_ids), jnp.asarray(w_ids)
    s_jax, _, ratios_f32 = mh.mh_chain(
        jnp.asarray(s0), jnp.asarray(t_doc), jnp.asarray(t_word),
        jnp.asarray(u_acc),
        lookup_d=lambda k: Dj[dj, k].astype(jnp.float32),
        lookup_w=lambda k: Wj[wj, k],
        lookup_q=lambda k: tables.q[wj, k],
        alpha=0.1, return_ratios=True)
    s_ref, ratios_f64 = mh.reference_chain_numpy(
        s0, t_doc, t_word, u_acc, d_ids, w_ids, D, W_hat,
        np.asarray(tables.q), alpha=0.1)

    rel = np.abs(np.asarray(ratios_f32, np.float64) - ratios_f64) \
        / np.maximum(ratios_f64, 1e-30)
    assert float(rel.max()) < 1e-4

    # topics must agree exactly wherever every predicate is decided by a
    # margin f32 rounding cannot flip
    margin = np.min(np.abs(u_acc.astype(np.float64) - ratios_f64), axis=(0, 1))
    safe = margin > 1e-4
    assert safe.mean() > 0.9
    assert np.array_equal(np.asarray(s_jax)[safe], s_ref[safe])


# ---------------------------------------------------------------------------
# 3. stationarity: warp vs exact LLPT plateau
# ---------------------------------------------------------------------------

def _final_llpt(corpus, sampler, seed):
    cfg = LDAConfig(n_topics=16, tile_size=512, eval_every=100,
                    sampler=sampler, fused=True, seed=seed)
    tr = LDATrainer(corpus, cfg, _from_engine=True)
    pipe = tr.fused_pipeline()
    fs = pipe.from_lda_state(tr.init_state())
    init = tr.evaluate(pipe.to_lda_state(fs))
    fs, _, _ = pipe.run_fused(fs, 100)
    return init, tr.evaluate(pipe.to_lda_state(fs))


@pytest.mark.slow
def test_warp_stationary_distribution_matches_exact(small_corpus):
    gaps = []
    for seed in (0, 1):
        init_w, warp = _final_llpt(small_corpus, "warp", seed)
        _, exact = _final_llpt(small_corpus, "three_branch", seed)
        assert warp > init_w + 0.2        # actually converged, not stuck
        gaps.append(abs(warp - exact))
    # measured ~0.04-0.07 nats/token on this corpus; 0.15 flags a chain
    # targeting the wrong stationary distribution without being flaky
    assert max(gaps) < 0.15, gaps


# ---------------------------------------------------------------------------
# 4. path equivalences, all bitwise
# ---------------------------------------------------------------------------

def test_fused_warp_equals_stepwise_bitwise(small_corpus):
    tr_s = LDATrainer(small_corpus, LDAConfig(**BASE), _from_engine=True)
    tr_f = LDATrainer(small_corpus, LDAConfig(**BASE, fused=True), _from_engine=True)
    pipe = tr_f.fused_pipeline()
    fs = pipe.from_lda_state(tr_f.init_state())
    st_ref = tr_s.init_state()
    for _ in range(3):
        fs, _, _ = pipe.step(fs)
        st_ref, _ = tr_s.step(st_ref)
    assert np.array_equal(np.asarray(fs.topics), np.asarray(st_ref.topics))
    assert np.array_equal(np.asarray(fs.D), np.asarray(st_ref.D))
    pipe.selfcheck(fs)


@pytest.fixture(scope="module")
def wide_corpus():
    # V large enough that the plan_window(64..128) tile window satisfies
    # win·4 <= V and the tiled kernel path actually engages
    c = zipf_corpus(seed=7, n_docs=100, n_words=600, mean_doc_len=50)
    c, _ = relabel_by_frequency(c)
    return c


def _run5(corpus, **over):
    cfg = LDAConfig(**{**BASE, "fused": True, **over})
    tr = LDATrainer(corpus, cfg, _from_engine=True)
    pipe = tr.fused_pipeline()
    fs = pipe.from_lda_state(tr.init_state())
    fs, stats, _ = pipe.run_fused(fs, 5)
    return pipe, fs, stats


def test_pallas_warp_equals_xla_bitwise(wide_corpus):
    _, fx, _ = _run5(wide_corpus, survivor_capacity=64)
    pp, fp, _ = _run5(wide_corpus, survivor_capacity=64, impl="pallas")
    assert np.array_equal(np.asarray(fp.topics), np.asarray(fx.topics))
    assert np.array_equal(np.asarray(fp.W), np.asarray(fx.W))

    pt, ft, _ = _run5(wide_corpus, survivor_capacity=64, impl="pallas",
                      balance="tiles")
    assert pt._use_tiles(pt.win_words)    # window engaged, not fallback
    assert np.array_equal(np.asarray(ft.topics), np.asarray(fx.topics))


def test_pallas_warp_window_fallback(small_corpus):
    # V=80 forces win == V: the cond must fall back to the full-vocab
    # window and still be bit-equal
    _, fx, _ = _run5(small_corpus)
    pp, fp, _ = _run5(small_corpus, impl="pallas", balance="tiles")
    assert not pp._use_tiles(pp.win_words)
    assert np.array_equal(np.asarray(fp.topics), np.asarray(fx.topics))


def test_hybrid_warp_equals_dense_bitwise(small_corpus):
    _, fd, _ = _run5(small_corpus)
    ph, fh, _ = _run5(small_corpus, format="hybrid")
    ph.selfcheck(fh)
    assert np.array_equal(np.asarray(fh.topics), np.asarray(fd.topics))


def test_warp_selfcheck_runs_alias_invariants(small_corpus):
    _run5(small_corpus, selfcheck=True)


# ---------------------------------------------------------------------------
# 5. config surface + stats
# ---------------------------------------------------------------------------

def test_warp_stats_surface(small_corpus):
    tr = LDATrainer(small_corpus, LDAConfig(**BASE, mh_cycles=3), _from_engine=True)
    state = tr.init_state()
    state, stats = tr.step(state)
    assert stats["n_proposals"] == pytest.approx(6.0)
    assert 0.0 < stats["frac_accepted"] <= 1.0
    assert 0.0 <= stats["frac_unchanged"] <= 1.0


@pytest.mark.parametrize("knob,value,expect", [
    ("sampler", "bogus", ["two_branch", "three_branch", "warp"]),
    ("impl", "cuda", ["xla", "pallas"]),
    ("balance", "lpt", ["none", "tiles"]),
])
def test_config_rejects_unknown_with_valid_options(knob, value, expect):
    with pytest.raises(ValueError) as e:
        LDAConfig(n_topics=8, **{knob: value})
    msg = str(e.value)
    assert "valid options" in msg
    for option in expect:
        assert option in msg


def test_config_rejects_nonpositive_mh_cycles():
    with pytest.raises(ValueError, match="mh_cycles"):
        LDAConfig(n_topics=8, mh_cycles=0)


def test_streamed_rejects_warp(small_corpus):
    tr = LDATrainer(small_corpus, LDAConfig(
        **BASE, fused=True, corpus_residency="streamed", stream_shards=2), _from_engine=True)
    with pytest.raises(ValueError, match="streamed"):
        tr.fused_pipeline()


def test_distributed_rejects_warp(small_corpus):
    from repro.lda.distributed import DistLDATrainer
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with pytest.raises(ValueError, match="single-backend|backend='single'"):
        DistLDATrainer(small_corpus, LDAConfig(**BASE), mesh, _from_engine=True)
