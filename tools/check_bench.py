"""Benchmark-regression check (the CI perf gate).

Every committed ``results/BENCH_*.json`` is validated two ways:

  1. **Schema** — the file must carry exactly the documented structure
     (docs/BENCHMARKS.md): required keys, value types, non-empty cell
     lists. A driver that silently changes its output shape fails CI
     instead of rotting the docs.
  2. **Key metrics** — the measured numbers that PRs have claimed as
     wins are pinned against their documented bounds with a tolerance
     band (``--tolerance``, default 5% on ratio bounds): e.g. the hybrid
     live state may not slow the fused step beyond 1.25×, tile
     scheduling may not cost throughput, streaming must keep its memory
     win. A regression that would quietly undo a measured speedup turns
     the build red.

``--dry-run-schema-only PATH`` validates schema without metric gates —
for the CI smoke artifacts (e.g. ``BENCH_serve_lda_dryrun.json``) whose
numbers come from a seconds-long dry run and mean nothing.

Usage:
    python tools/check_bench.py                 # all results/BENCH_*.json
    python tools/check_bench.py results/BENCH_balance.json
    python tools/check_bench.py --dry-run-schema-only results/BENCH_serve_lda_dryrun.json

Exits nonzero with a list of failures.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

NUM = (int, float)

# -- schemas (mirrors docs/BENCHMARKS.md) -----------------------------------

_CORPUS = {"docs": int, "words": int, "tokens": int}

SCHEMAS: dict[str, dict] = {
    "BENCH_fused_step.json": {
        "corpus": _CORPUS, "n_topics": int,
        "warmup_iters": int, "timed_iters": int, "repeats": int,
        "seed_tokens_per_sec": NUM, "fused_tokens_per_sec": NUM,
        "speedup": NUM,
        "hybrid_tokens_per_sec": NUM, "hybrid_slowdown_factor": NUM,
        "hybrid_state_bytes": int, "dense_state_bytes": int,
        "host_syncs_in_scanned_region": int,
        "phase2_impl": str, "survivor_capacity": int,
    },
    "BENCH_hybrid_state.json": {
        "corpus": _CORPUS, "n_topics": int,
        "d_capacity_bound": int, "dense_state_bytes": int,
        "cells": [{"d_capacity": int, "dense_word_threshold": int,
                   "v_dense": int, "tokens_per_sec": NUM,
                   "state_bytes": int, "vs_dense_bytes": NUM}],
    },
    "BENCH_balance.json": {
        "corpus": {**_CORPUS, "exponent": NUM}, "n_topics": int,
        "schemes": [{"scheme": str, "max": int, "mean": NUM,
                     "imbalance": NUM}],
        "tile_plan": {"tile_size": int, "n_tiles": int,
                      "max_words_per_tile": int, "max_tiles_per_word": int},
        "shard_loads": {"doc_chunking": NUM, "token_tiles": NUM},
        "throughput": {"warmup_iters": int, "timed_iters": int,
                       "repeats": int, "untiled_tokens_per_sec": NUM,
                       "tiled_tokens_per_sec": NUM,
                       "tiled_over_untiled": NUM, "win_words": int,
                       "tiled_capacity": int, "untiled_capacity": int},
    },
    "BENCH_serve_lda.json": {
        "dry_run": bool,
        "model": {"n_words": int, "n_topics": int, "g": int},
        "train": {"docs": int, "tokens": int, "iters": int,
                  "seconds": NUM},
        "host_syncs_in_dispatch": int, "repeats": int,
        "cells": [{"batch_size": int, "n_sweeps": int,
                   "padded_tokens": int, "docs_per_sec": NUM,
                   "docs_per_sec_dispatch": NUM, "held_out_llpt": NUM,
                   "theta_shape": [int]}],
        "best_docs_per_sec": NUM, "best_cell": dict,
    },
    "BENCH_streaming.json": {
        "corpus": _CORPUS, "n_topics": int, "n_shards": int,
        "warmup_iters": int, "timed_iters": int, "repeats": int,
        "resident_tokens_per_sec": NUM, "streamed_tokens_per_sec": NUM,
        "streamed_over_resident": NUM,
        "resident_device_bytes": int, "streamed_device_bytes": int,
        "streamed_bytes_ratio": NUM,
        "bitwise_equal_to_resident": bool,
    },
    "BENCH_disk_streaming.json": {
        "dry_run": bool,
        "corpus": _CORPUS, "n_topics": int, "n_shards": int,
        "shard_len": int, "paged_rows": int, "vocab_rows": int,
        "store_bytes": int,
        "warmup_iters": int, "timed_iters": int, "repeats": int,
        "resident_tokens_per_sec": NUM, "disk_tokens_per_sec": NUM,
        "disk_over_resident": NUM,
        "resident_device_bytes": int, "disk_device_bytes": int,
        "disk_bytes_ratio": NUM,
        "bitwise_equal_to_resident": bool,
        "eval_equal_to_resident": bool,
    },
    "BENCH_warp_sampler.json": {
        "dry_run": bool,
        "corpus": _CORPUS, "n_topics": int,
        "warmup_iters": int, "timed_iters": int, "repeats": int,
        "conv_iters": int, "eval_every": int,
        "exact_tokens_per_sec": NUM, "exact_final_llpt": NUM,
        "exact_curve": [{"seconds": NUM, "llpt": NUM}],
        "cells": [{"mh_cycles": int, "tokens_per_sec": NUM,
                   "warp_over_exact": NUM, "final_llpt": NUM,
                   "final_llpt_gap": NUM,
                   "curve": [{"seconds": NUM, "llpt": NUM}]}],
        "warp_tokens_per_sec": NUM, "warp_over_exact": NUM,
        "min_llpt_gap": NUM,
        "host_syncs_in_scanned_region": int,
    },
    "BENCH_serve_service.json": {
        "dry_run": bool,
        "model": {"n_words": int, "n_topics": int, "g": int},
        "train": {"docs": int, "tokens": int, "iters": int,
                  "seconds": NUM},
        "serve": {"n_replicas": int, "n_sweeps": int, "warm_start": bool,
                  "hot_words": int, "max_batch": int, "max_delay_ms": NUM,
                  "buckets": [int], "warmed_signatures": int},
        "stream": {"zipf_exponent": NUM, "mean_doc_len": int,
                   "n_docs": int},
        "batch_mode_best_docs_per_sec": NUM, "batch_mode_source": str,
        "saturation": {"docs": int, "seconds": NUM, "docs_per_sec": NUM,
                       "docs_per_sec_overall": NUM, "ramp_docs": int,
                       "batch_fill": NUM},
        "speedup_vs_batch": NUM,
        "half_load": {"offered_docs_per_sec": NUM, "completed": int,
                      "p50_ms": NUM, "p95_ms": NUM, "p99_ms": NUM,
                      "p99_over_p50": NUM},
        "cache_hit_rate": NUM,
        "completion": {"submitted": int, "completed": int, "failed": int,
                       "rejected": int, "rate": NUM},
        "quality": {"llpt_serve": NUM, "llpt_batch5": NUM,
                    "delta_bits": NUM},
    },
    "BENCH_ps_scaling.json": {
        "dry_run": bool,
        "corpus": _CORPUS, "n_topics": int,
        "warmup_iters": int, "timed_iters": int, "repeats": int,
        "cells": [{"n_workers": int, "n_owners": int,
                   "replicated_w_bytes": int, "max_owner_bytes": int,
                   "owner_frac": NUM,
                   "per_host_state_bytes": int,
                   "replicated_state_bytes": int, "state_frac": NUM,
                   "replicated_tokens_per_sec": NUM,
                   "ps_tokens_per_sec": NUM, "ps_over_replicated": NUM,
                   "bitwise_equal_to_replicated": bool}],
        "max_workers": int,
        "owner_frac_at_max": NUM,
        "staleness0_bitwise": bool,
    },
    "BENCH_recovery.json": {
        "corpus": _CORPUS, "n_topics": int,
        "n_iters": int, "checkpoint_every": int, "repeats": int,
        "unsupervised_tokens_per_sec": NUM,
        "supervised_tokens_per_sec": NUM,
        "supervised_over_unsupervised": NUM,
        "recovery_iters": int, "restarts": int,
        "recovery_seconds_per_restart": NUM,
        "bitwise_equal_after_recovery": bool,
    },
}

# smoke artifacts reuse a driver's schema but skip the metric gates
SCHEMA_ALIASES = {
    "BENCH_disk_streaming_dryrun.json": "BENCH_disk_streaming.json",
    "BENCH_ps_scaling_dryrun.json": "BENCH_ps_scaling.json",
    "BENCH_serve_lda_dryrun.json": "BENCH_serve_lda.json",
    "BENCH_serve_service_dryrun.json": "BENCH_serve_service.json",
    "BENCH_warp_sampler_dryrun.json": "BENCH_warp_sampler.json",
}


# -- key-metric gates (the bounds PRs have claimed; tolerance on ratios) ----

def _scheme(doc, name):
    for row in doc["schemes"]:
        if row["scheme"] == name:
            return row
    raise KeyError(f"scheme {name!r} missing")


# (metric description, getter, op, bound, toleranced?)
GATES: dict[str, list] = {
    "BENCH_fused_step.json": [
        ("fused/seed speedup", lambda d: d["speedup"], ">=", 2.0, True),
        ("hybrid_slowdown_factor", lambda d: d["hybrid_slowdown_factor"],
         "<=", 1.25, True),
        ("hybrid/dense state bytes", lambda d: d["hybrid_state_bytes"]
         / d["dense_state_bytes"], "<=", 0.6, True),
        ("host_syncs_in_scanned_region",
         lambda d: d["host_syncs_in_scanned_region"], "==", 0, False),
    ],
    "BENCH_hybrid_state.json": [
        ("best vs_dense_bytes", lambda d: min(c["vs_dense_bytes"]
                                              for c in d["cells"]),
         "<=", 0.6, True),
    ],
    "BENCH_balance.json": [
        ("token_tiles lane imbalance",
         lambda d: _scheme(d, "token_tiles")["imbalance"], "<=", 1.2, True),
        ("token_tiles shard imbalance",
         lambda d: d["shard_loads"]["token_tiles"], "<=", 1.05, True),
        ("tiled/untiled throughput",
         lambda d: d["throughput"]["tiled_over_untiled"], ">=", 1.0, True),
    ],
    "BENCH_serve_lda.json": [
        ("host_syncs_in_dispatch", lambda d: d["host_syncs_in_dispatch"],
         "==", 0, False),
        ("best_docs_per_sec", lambda d: d["best_docs_per_sec"], ">", 0.0,
         False),
    ],
    "BENCH_streaming.json": [
        ("streamed/resident device bytes",
         lambda d: d["streamed_bytes_ratio"], "<=", 0.6, True),
        ("streamed/resident throughput",
         lambda d: d["streamed_over_resident"], ">=", 0.8, True),
        ("streamed == resident bitwise",
         lambda d: d["bitwise_equal_to_resident"], "==", True, False),
        ("stream shard count", lambda d: d["n_shards"], ">=", 4, False),
    ],
    "BENCH_disk_streaming.json": [
        ("disk/resident device bytes",
         lambda d: d["disk_bytes_ratio"], "<=", 0.45, True),
        ("disk/resident throughput",
         lambda d: d["disk_over_resident"], ">=", 0.7, True),
        ("W page window a strict vocab slice",
         lambda d: d["paged_rows"] / d["vocab_rows"], "<=", 0.25, True),
        ("disk == resident bitwise",
         lambda d: d["bitwise_equal_to_resident"], "==", True, False),
        ("paged eval == resident eval",
         lambda d: d["eval_equal_to_resident"], "==", True, False),
        ("disk shard count", lambda d: d["n_shards"], ">=", 8, False),
    ],
    "BENCH_warp_sampler.json": [
        ("warp/exact tokens-per-sec at default mh_cycles",
         lambda d: d["warp_over_exact"], ">=", 2.0, True),
        ("measured at K >= 256", lambda d: d["n_topics"], ">=", 256,
         False),
        ("host_syncs_in_scanned_region",
         lambda d: d["host_syncs_in_scanned_region"], "==", 0, False),
        ("best-cell LLPT plateau gap vs exact",
         lambda d: d["min_llpt_gap"], "<=", 0.15, True),
    ],
    "BENCH_serve_service.json": [
        ("service/batch saturation speedup",
         lambda d: d["speedup_vs_batch"], ">=", 3.0, True),
        ("half-load p99/p50 latency ratio",
         lambda d: d["half_load"]["p99_over_p50"], "<=", 5.0, True),
        ("cache hit rate on Zipf stream",
         lambda d: d["cache_hit_rate"], ">=", 0.8, True),
        ("every submitted request completed",
         lambda d: d["completion"]["rate"], "==", 1.0, False),
        ("serve-vs-batch LLPT gap (bits)",
         lambda d: d["quality"]["delta_bits"], "<=", 0.1, True),
    ],
    "BENCH_ps_scaling.json": [
        ("per-host W-owner bytes vs one replicated W copy",
         lambda d: d["owner_frac_at_max"], "<=", 0.35, True),
        ("staleness=0 PS == replicated bitwise (every worker count)",
         lambda d: d["staleness0_bitwise"], "==", True, False),
        ("measured out to >= 8 workers", lambda d: d["max_workers"],
         ">=", 8, False),
    ],
    "BENCH_recovery.json": [
        ("supervised/unsupervised throughput",
         lambda d: d["supervised_over_unsupervised"], ">=", 0.95, True),
        ("recovery exercised a restart", lambda d: d["restarts"], ">=", 1,
         False),
        ("recovered == uninterrupted bitwise",
         lambda d: d["bitwise_equal_after_recovery"], "==", True, False),
    ],
}


# -- validation machinery ----------------------------------------------------

def check_schema(obj, spec, path: str) -> list[str]:
    errors: list[str] = []
    if isinstance(spec, dict):
        if not isinstance(obj, dict):
            return [f"{path}: expected object, got {type(obj).__name__}"]
        if not spec:           # free-form object (e.g. best_cell)
            return []
        for key, sub in spec.items():
            if key not in obj:
                errors.append(f"{path}.{key}: missing")
            else:
                errors += check_schema(obj[key], sub, f"{path}.{key}")
    elif isinstance(spec, list):
        if not isinstance(obj, list):
            return [f"{path}: expected array, got {type(obj).__name__}"]
        if not obj:
            return [f"{path}: empty array"]
        for i, item in enumerate(obj):
            errors += check_schema(item, spec[0], f"{path}[{i}]")
    elif spec is dict:
        if not isinstance(obj, dict):
            errors.append(f"{path}: expected object")
    else:
        # bool is an int subclass: keep int gates honest
        ok = isinstance(obj, spec) and not (
            spec in (int, NUM) and isinstance(obj, bool))
        if not ok:
            errors.append(f"{path}: expected {spec}, got "
                          f"{type(obj).__name__} ({obj!r})")
    return errors


def check_gates(doc, gates, tolerance: float) -> list[str]:
    errors = []
    for desc, getter, op, bound, toleranced in gates:
        try:
            value = getter(doc)
        except Exception as e:                 # missing path == schema rot
            errors.append(f"{desc}: unreadable ({type(e).__name__}: {e})")
            continue
        lo = bound * (1 - tolerance) if toleranced else bound
        hi = bound * (1 + tolerance) if toleranced else bound
        ok = {"<=": value <= hi, ">=": value >= lo,
              ">": value > bound, "==": value == bound}[op]
        if not ok:
            band = f" (±{tolerance:.0%} band)" if toleranced else ""
            errors.append(f"{desc} = {value!r} violates {op} {bound}{band}")
    return errors


def check_file(path: str, tolerance: float,
               schema_only: bool = False) -> list[str]:
    name = os.path.basename(path)
    schema_name = SCHEMA_ALIASES.get(name, name)
    if schema_name not in SCHEMAS:
        return [f"{name}: no documented schema — add it to "
                "docs/BENCHMARKS.md and tools/check_bench.py"]
    try:
        doc = json.load(open(path))
    except (OSError, ValueError) as e:
        return [f"{name}: unreadable JSON ({e})"]
    errors = [f"{name}: {e}"
              for e in check_schema(doc, SCHEMAS[schema_name], "$")]
    if not errors and not schema_only:
        errors += [f"{name}: {e}" for e in
                   check_gates(doc, GATES.get(schema_name, []), tolerance)]
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="validate results/BENCH_*.json against documented "
                    "schemas and committed key-metric bounds")
    ap.add_argument("files", nargs="*",
                    help="BENCH json files (default: results/BENCH_*.json, "
                         "smoke artifacts excluded)")
    ap.add_argument("--tolerance", type=float, default=0.05,
                    help="multiplicative band on ratio bounds (default 5%%)")
    ap.add_argument("--dry-run-schema-only", action="store_true",
                    help="validate schema only, skip metric gates (CI "
                         "smoke artifacts)")
    args = ap.parse_args(argv)
    files = args.files or sorted(
        f for f in glob.glob(os.path.join(ROOT, "results", "BENCH_*.json"))
        if os.path.basename(f) not in SCHEMA_ALIASES)
    if not files:
        print("check_bench: no BENCH files found", file=sys.stderr)
        return 1
    failures = []
    for path in files:
        errs = check_file(path, args.tolerance,
                          schema_only=args.dry_run_schema_only)
        failures += errs
        status = "FAIL" if errs else \
            ("schema OK" if args.dry_run_schema_only else "OK")
        print(f"check_bench: {os.path.basename(path)}: {status}")
    for e in failures:
        print(f"BENCH-REGRESSION: {e}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
