"""Docs-integrity check (the CI docs step).

Two gates, so the docs surface cannot silently rot:

  1. Markdown link check: every relative link/anchor in README.md,
     DESIGN.md, and docs/*.md must resolve to an existing file (and,
     for ``#fragment`` links, to a heading slug in the target file).
     External (``http``/``https``/``mailto``) links are not fetched.
  2. API-reference import check: every dotted ``repro.*`` symbol named
     in docs/API.md must import — module attributes are resolved with
     ``getattr`` after importing the longest importable module prefix —
     so the reference cannot drift from the actual public surface.

Usage: PYTHONPATH=src python tools/check_docs.py
Exits nonzero with a list of failures.
"""

from __future__ import annotations

import glob
import importlib
import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SYMBOL_RE = re.compile(r"\brepro(?:\.\w+)+")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)


def _slug(heading: str) -> str:
    """GitHub-style anchor slug (enough for our own docs)."""
    h = re.sub(r"`([^`]*)`", r"\1", heading).strip().lower()
    h = re.sub(r"[^\w\s-]", "", h)
    return re.sub(r"[\s]+", "-", h)


def _doc_files() -> list[str]:
    files = [os.path.join(ROOT, "README.md"), os.path.join(ROOT, "DESIGN.md")]
    files += sorted(glob.glob(os.path.join(ROOT, "docs", "*.md")))
    return [f for f in files if os.path.exists(f)]


def check_links() -> list[str]:
    errors = []
    for path in _doc_files():
        text = open(path).read()
        base = os.path.dirname(path)
        rel = os.path.relpath(path, ROOT)
        for target in LINK_RE.findall(text):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            file_part, _, frag = target.partition("#")
            tgt_path = path if not file_part \
                else os.path.normpath(os.path.join(base, file_part))
            if not os.path.exists(tgt_path):
                errors.append(f"{rel}: broken link -> {target}")
                continue
            if frag and tgt_path.endswith(".md"):
                slugs = {_slug(h)
                         for h in HEADING_RE.findall(open(tgt_path).read())}
                if frag.lower() not in slugs:
                    errors.append(f"{rel}: broken anchor -> {target}")
    return errors


def check_api_symbols() -> list[str]:
    api_md = os.path.join(ROOT, "docs", "API.md")
    if not os.path.exists(api_md):
        return ["docs/API.md is missing"]
    errors = []
    for name in sorted(set(SYMBOL_RE.findall(open(api_md).read()))):
        parts = name.split(".")
        mod, attrs = None, []
        for cut in range(len(parts), 0, -1):
            try:
                mod = importlib.import_module(".".join(parts[:cut]))
                attrs = parts[cut:]
                break
            except ImportError:
                continue
        if mod is None:
            errors.append(f"docs/API.md names unimportable module: {name}")
            continue
        obj = mod
        for a in attrs:
            if not hasattr(obj, a):
                errors.append(f"docs/API.md names missing symbol: {name}")
                break
            obj = getattr(obj, a)
    return errors


def main() -> int:
    errors = check_links() + check_api_symbols()
    for e in errors:
        print(f"DOCS-INTEGRITY: {e}", file=sys.stderr)
    if not errors:
        n_files = len(_doc_files())
        print(f"docs-integrity OK ({n_files} markdown files, links + "
              "API symbols verified)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
